//! Sketched gradient communication with error feedback (DESIGN.md §11):
//! the count-sketch as a **wire compressor** for `mode = comm-sketch`.
//!
//! `mode = data` all-reduces dense `[vocab, d]` gradient segments every
//! step — untenable at the paper's 800K-row lm1b scale. But the
//! count-sketch is *linear*: the sketch of a sum is the sum of sketches,
//! so ranks can sketch their local gradients, `all_reduce_sum` the
//! (much smaller) sketch buffers, and recover the heavy coordinates of
//! the **global** gradient from the aggregate — the FetchSGD recipe
//! (Rothchild et al. 2020) built from this repo's own
//! [`SketchHasher`]/[`SketchPlan`]/[`median_rows`] primitives.
//!
//! Per gradient segment (emb / sm / bias / trunk) a [`SegmentSketcher`]
//! keeps two persistent `[depth · width]` sketches beside the per-step
//! encode:
//!
//! * **momentum** — `M ← ρ·M + S(g)` accumulates the aggregated
//!   gradient sketch in sketch space (momentum *inside* the sketch,
//!   FetchSGD §3);
//! * **error feedback** — `E ← E + M`, then the recovered top-k
//!   coordinates' cells are **zeroed out** of `E`. Zeroing (rather than
//!   subtracting the recovered estimates) removes exactly the mass the
//!   optimizer consumed *plus* the collision noise in those cells, so
//!   stale noise cannot recirculate — the FetchSGD stabilization.
//!
//! `decode` queries the error sketch at a bounded candidate set (the
//! activity-mask row union the data-parallel exchange already computes),
//! takes [`abs_top_k`], and emits a sparse `(ids, vals)` update for the
//! ordinary clip + optimizer step path.
//!
//! **Determinism boundary.** Everything after the exchange is a pure
//! function of the aggregated sketch bits, and the exchange itself gives
//! every replica's slot exactly one owner (zeros elsewhere), so the sum
//! reconstructs each slot bit-for-bit and every rank decodes identical
//! updates from identical momentum/error state — the lossy mode is still
//! bitwise-deterministic across process layouts. What is *lost* is only
//! the gradient information outside the recovered top-k (kept, damped,
//! in the error sketch).

use crate::sketch::store::median_rows;
use crate::sketch::{SketchHasher, SketchPlan};
use crate::util::rng::splitmix64;

/// Indices of the `k` largest-magnitude entries of `vals`, ties broken
/// toward the **lower index**, returned in ascending index order. Exact
/// zeros are never selected (a zero recovered coordinate is a no-op
/// update), so an all-zero input yields an empty set and `k ≥ len`
/// yields every nonzero index.
pub fn abs_top_k(vals: &[f32], k: usize) -> Vec<usize> {
    let mut order: Vec<usize> = (0..vals.len()).filter(|&i| vals[i] != 0.0).collect();
    order.sort_by(|&a, &b| {
        vals[b]
            .abs()
            .total_cmp(&vals[a].abs())
            .then_with(|| a.cmp(&b))
    });
    order.truncate(k);
    order.sort_unstable();
    order
}

/// `[dist]` comm-sketch geometry: one knob set shared by all four
/// segment sketchers (each caps its own width via [`segment_width`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GradSketchCfg {
    /// Sketch depth (`comm_d`).
    pub depth: usize,
    /// Sketch width before the per-segment cap (`comm_w`).
    pub width: usize,
    /// Coordinates recovered per segment per step (`comm_k`).
    pub k: usize,
    /// Sketch-space momentum coefficient `ρ ∈ [0, 1)` (`comm_momentum`).
    pub momentum: f32,
    /// Hash-family master seed (segments decorrelate from it).
    pub seed: u64,
}

/// The effective sketch width for a segment of `seg_len` coordinates:
/// the configured width, capped so the sketch never exceeds **half** the
/// dense segment (`depth · width ≤ seg_len / 2`) — compressing a segment
/// into something larger than itself would be pure overhead.
pub fn segment_width(width: usize, depth: usize, seg_len: usize) -> usize {
    width.min((seg_len / (2 * depth)).max(1))
}

/// One gradient segment's compressor: a hash family for the per-step
/// encode plus the persistent momentum and error-feedback sketches the
/// decode folds the aggregate through. All three share the family — the
/// error sketch accumulates in the *same* cells the encode writes, which
/// is what makes `E ← E + M` meaningful.
pub struct SegmentSketcher {
    hasher: SketchHasher,
    depth: usize,
    width: usize,
    /// `[depth · width]` sketch-space momentum `M`.
    momentum: Vec<f32>,
    /// `[depth · width]` error-feedback accumulator `E`.
    error: Vec<f32>,
    /// Plan scratch for ids the caller does not plan itself.
    plan: SketchPlan,
    /// Candidate-estimate scratch for `decode_into`.
    est: Vec<f32>,
    /// Median scratch (`depth > 3` only).
    med: Vec<f32>,
}

impl SegmentSketcher {
    pub fn new(depth: usize, width: usize, seed: u64) -> SegmentSketcher {
        assert!(depth >= 1 && width >= 1);
        SegmentSketcher {
            hasher: SketchHasher::new(depth, width, seed),
            depth,
            width,
            momentum: vec![0.0; depth * width],
            error: vec![0.0; depth * width],
            plan: SketchPlan::new(),
            est: Vec::new(),
            med: if depth > 3 { vec![0.0; depth] } else { Vec::new() },
        }
    }

    /// Sketch buffer length (`depth · width`) — the segment's wire size.
    pub fn sketch_len(&self) -> usize {
        self.depth * self.width
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// Build a reusable plan for a fixed id set under this segment's
    /// family (e.g. the trunk's static `0..flat_len` coordinates).
    pub fn plan_for(&self, ids: &[u64]) -> SketchPlan {
        SketchPlan::build(&self.hasher, ids)
    }

    /// ENCODE: scatter-add `sign_j(id) · val` into `out[j·w + bucket_j(id)]`
    /// for every depth row, replaying a prebuilt `plan` over `vals`'
    /// coordinate ids. `out` is the segment's slice of the exchange
    /// buffer; additive, so the caller zeroes it once per step.
    pub fn encode_with(&self, plan: &SketchPlan, vals: &[f32], out: &mut [f32]) {
        debug_assert!(plan.compatible(&self.hasher), "plan from a different family");
        assert_eq!(plan.k(), vals.len());
        assert_eq!(out.len(), self.sketch_len());
        for j in 0..self.depth {
            let row = &mut out[j * self.width..(j + 1) * self.width];
            for (t, &v) in vals.iter().enumerate() {
                row[plan.bucket(j, t)] += plan.sign(j, t) * v;
            }
        }
    }

    /// [`SegmentSketcher::encode_with`] over ad-hoc ids (plans them into
    /// the internal scratch first).
    pub fn encode(&mut self, ids: &[u64], vals: &[f32], out: &mut [f32]) {
        let mut plan = std::mem::take(&mut self.plan);
        plan.rebuild(&self.hasher, ids);
        self.encode_with(&plan, vals, out);
        self.plan = plan;
    }

    /// DECODE one aggregated (averaged) gradient sketch `agg` into a
    /// sparse update: fold it through momentum (`M ← ρ·M + agg`) and
    /// error feedback (`E ← E + M`), query `E` at `cand` (signed median
    /// over depth), keep the [`abs_top_k`] candidates as
    /// `(out_ids, out_vals)`, and zero the recovered coordinates' cells
    /// out of `E`. Deterministic: a pure function of `agg`, the sketch
    /// state and the candidate list — identical on every rank.
    #[allow(clippy::too_many_arguments)]
    pub fn decode_with(
        &mut self,
        agg: &[f32],
        momentum_coef: f32,
        plan: &SketchPlan,
        cand: &[u64],
        k: usize,
        out_ids: &mut Vec<u64>,
        out_vals: &mut Vec<f32>,
    ) {
        debug_assert!(plan.compatible(&self.hasher), "plan from a different family");
        assert_eq!(agg.len(), self.sketch_len());
        assert_eq!(plan.k(), cand.len());
        for ((m, e), &a) in self.momentum.iter_mut().zip(self.error.iter_mut()).zip(agg) {
            *m = momentum_coef * *m + a;
            *e += *m;
        }
        self.est.clear();
        self.est.resize(cand.len(), 0.0);
        let mut rows = [(0usize, 0.0f32); 8];
        for t in 0..cand.len() {
            if self.depth <= rows.len() {
                for (j, row) in rows[..self.depth].iter_mut().enumerate() {
                    *row = (j * self.width + plan.bucket(j, t), plan.sign(j, t));
                }
                median_rows(
                    &self.error,
                    1,
                    &rows[..self.depth],
                    &mut self.med,
                    &mut self.est[t..t + 1],
                );
            } else {
                let heap: Vec<(usize, f32)> = (0..self.depth)
                    .map(|j| (j * self.width + plan.bucket(j, t), plan.sign(j, t)))
                    .collect();
                median_rows(&self.error, 1, &heap, &mut self.med, &mut self.est[t..t + 1]);
            }
        }
        out_ids.clear();
        out_vals.clear();
        for t in abs_top_k(&self.est, k) {
            out_ids.push(cand[t]);
            out_vals.push(self.est[t]);
            for j in 0..self.depth {
                self.error[j * self.width + plan.bucket(j, t)] = 0.0;
            }
        }
    }

    /// [`SegmentSketcher::decode_with`] over ad-hoc candidates.
    #[allow(clippy::too_many_arguments)]
    pub fn decode(
        &mut self,
        agg: &[f32],
        momentum_coef: f32,
        cand: &[u64],
        k: usize,
        out_ids: &mut Vec<u64>,
        out_vals: &mut Vec<f32>,
    ) {
        let mut plan = std::mem::take(&mut self.plan);
        plan.rebuild(&self.hasher, cand);
        self.decode_with(agg, momentum_coef, &plan, cand, k, out_ids, out_vals);
        self.plan = plan;
    }

    /// Reset the persistent sketch state (tests).
    pub fn reset(&mut self) {
        self.momentum.iter_mut().for_each(|x| *x = 0.0);
        self.error.iter_mut().for_each(|x| *x = 0.0);
    }

    /// The error-feedback sketch (diagnostics/tests).
    pub fn error_sketch(&self) -> &[f32] {
        &self.error
    }
}

/// The four-segment gradient compressor `mode = comm-sketch` trains
/// through: one [`SegmentSketcher`] per segment (emb, sm, bias, trunk),
/// each with a decorrelated hash family and a width capped to its
/// segment's dense length.
pub struct GradSketcher {
    pub segs: Vec<SegmentSketcher>,
    cfg: GradSketchCfg,
}

impl GradSketcher {
    /// Build one sketcher per entry of `seg_lens` (dense coordinate
    /// counts, in segment order).
    pub fn new(cfg: GradSketchCfg, seg_lens: &[usize]) -> GradSketcher {
        assert!(cfg.depth >= 1 && cfg.width >= 1 && cfg.k >= 1);
        assert!((0.0..1.0).contains(&cfg.momentum));
        let segs = seg_lens
            .iter()
            .enumerate()
            .map(|(i, &len)| {
                let w = segment_width(cfg.width, cfg.depth, len);
                SegmentSketcher::new(cfg.depth, w, splitmix64(cfg.seed ^ (i as u64 + 1)))
            })
            .collect();
        GradSketcher { segs, cfg }
    }

    pub fn cfg(&self) -> &GradSketchCfg {
        &self.cfg
    }

    /// Total wire size: the sum of the per-segment sketch lengths.
    pub fn sketch_len(&self) -> usize {
        self.segs.iter().map(|s| s.sketch_len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn abs_top_k_handles_ties_overlong_k_and_zeros() {
        // plain selection, ascending index order
        assert_eq!(abs_top_k(&[1.0, -5.0, 3.0, 0.5], 2), vec![1, 2]);
        // magnitude ties break toward the lower index
        assert_eq!(abs_top_k(&[-2.0, 2.0, 2.0], 1), vec![0]);
        assert_eq!(abs_top_k(&[-2.0, 2.0, 2.0], 2), vec![0, 1]);
        // k ≥ len keeps every nonzero entry
        assert_eq!(abs_top_k(&[1.0, 0.0, -1.0], 10), vec![0, 2]);
        // exact zeros are never recovered
        assert_eq!(abs_top_k(&[0.0, 0.0], 2), Vec::<usize>::new());
        assert_eq!(abs_top_k(&[], 3), Vec::<usize>::new());
        // k = 0 selects nothing
        assert_eq!(abs_top_k(&[4.0, 5.0], 0), Vec::<usize>::new());
    }

    /// Linearity, bitwise: on integer-valued grads (exact f32 arithmetic)
    /// `sketch(a) + sketch(b) == sketch(a + b)` exactly, across seeds and
    /// geometries. This is the property the wire protocol rides on.
    #[test]
    fn sketch_linearity_exact_on_integer_grids() {
        check("gradsketch-linearity", 40, 0x11EA, |rng| {
            let depth = 1 + rng.below(4);
            let width = 8 + rng.below(120);
            let n = 1 + rng.below(400);
            let sk = SegmentSketcher::new(depth, width, rng.next_u64());
            let ids: Vec<u64> = (0..n as u64).collect();
            // integer-valued floats keep every sum exact in f32
            let a: Vec<f32> = (0..n).map(|_| (rng.below(2001) as f32) - 1000.0).collect();
            let b: Vec<f32> = (0..n).map(|_| (rng.below(2001) as f32) - 1000.0).collect();
            let plan = sk.plan_for(&ids);
            let mut sa = vec![0.0f32; sk.sketch_len()];
            let mut sb = vec![0.0f32; sk.sketch_len()];
            let mut sab = vec![0.0f32; sk.sketch_len()];
            sk.encode_with(&plan, &a, &mut sa);
            sk.encode_with(&plan, &b, &mut sb);
            let ab: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
            sk.encode_with(&plan, &ab, &mut sab);
            for (i, ((&x, &y), &z)) in sa.iter().zip(&sb).zip(&sab).enumerate() {
                if (x + y).to_bits() != z.to_bits() {
                    return Err(format!("cell {i}: {x} + {y} != {z}"));
                }
            }
            Ok(())
        });
    }

    /// Disjoint supports: when two encoders touch disjoint id sets the
    /// sum-of-sketches equals the joint sketch bit-for-bit even for
    /// arbitrary float values — each cell contribution is added in the
    /// same order, and absent ids contribute exact zeros. This is the
    /// per-replica-slot ownership argument at sketch level.
    #[test]
    fn sketch_sum_of_disjoint_supports_is_bitwise() {
        check("gradsketch-disjoint", 40, 0xD15, |rng| {
            let depth = 1 + rng.below(3);
            let width = 16 + rng.below(64);
            let sk = SegmentSketcher::new(depth, width, rng.next_u64());
            let n = 2 + rng.below(200);
            let split = 1 + rng.below(n - 1);
            let ids: Vec<u64> = (0..n as u64).collect();
            let vals: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            // joint encode in id order
            let mut joint = vec![0.0f32; sk.sketch_len()];
            sk.encode_with(&sk.plan_for(&ids), &vals, &mut joint);
            // two disjoint encodes into the SAME buffer, lower ids first
            let mut parts = vec![0.0f32; sk.sketch_len()];
            sk.encode_with(&sk.plan_for(&ids[..split]), &vals[..split], &mut parts);
            sk.encode_with(&sk.plan_for(&ids[split..]), &vals[split..], &mut parts);
            for (i, (&a, &b)) in joint.iter().zip(&parts).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("cell {i}: joint {a} vs parts {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn decode_recovers_heavy_hitters_and_zeroes_their_cells() {
        let mut sk = SegmentSketcher::new(3, 256, 7);
        let n = 64u64;
        let ids: Vec<u64> = (0..n).collect();
        // two heavy coordinates over small noise
        let mut vals = vec![0.01f32; n as usize];
        vals[5] = 10.0;
        vals[40] = -8.0;
        let mut wire = vec![0.0f32; sk.sketch_len()];
        sk.encode(&ids, &vals, &mut wire);
        let (mut out_ids, mut out_vals) = (Vec::new(), Vec::new());
        sk.decode(&wire, 0.0, &ids, 2, &mut out_ids, &mut out_vals);
        assert_eq!(out_ids, vec![5, 40]);
        assert!((out_vals[0] - 10.0).abs() < 0.5, "{out_vals:?}");
        assert!((out_vals[1] + 8.0).abs() < 0.5, "{out_vals:?}");
        // recovered cells were zeroed: re-query sees ~nothing at 5/40
        let zero = vec![0.0f32; sk.sketch_len()];
        let (mut ids2, mut vals2) = (Vec::new(), Vec::new());
        sk.decode(&zero, 0.0, &ids, 2, &mut ids2, &mut vals2);
        for (id, v) in ids2.iter().zip(&vals2) {
            assert!(
                v.abs() < 0.5,
                "coordinate {id} still reads {v} after its cells were zeroed"
            );
        }
    }

    #[test]
    fn error_feedback_carries_unrecovered_mass_forward() {
        let mut sk = SegmentSketcher::new(3, 512, 3);
        let ids: Vec<u64> = (0..8).collect();
        let mut vals = vec![0.0f32; 8];
        vals[1] = 4.0;
        vals[6] = 3.0;
        let mut wire = vec![0.0f32; sk.sketch_len()];
        sk.encode(&ids, &vals, &mut wire);
        // k = 1: only coordinate 1 is recovered this step
        let (mut out_ids, mut out_vals) = (Vec::new(), Vec::new());
        sk.decode(&wire, 0.0, &ids, 1, &mut out_ids, &mut out_vals);
        assert_eq!(out_ids, vec![1]);
        // next step contributes nothing new, yet coordinate 6 surfaces
        // from the error sketch — the feedback loop at work
        let zero = vec![0.0f32; sk.sketch_len()];
        sk.decode(&zero, 0.0, &ids, 1, &mut out_ids, &mut out_vals);
        assert_eq!(out_ids, vec![6]);
        assert!((out_vals[0] - 3.0).abs() < 0.5, "{out_vals:?}");
    }

    #[test]
    fn momentum_scales_repeated_gradients() {
        // the same sketch fed twice under ρ = 0.5 must decode to
        // g·(1 + (1 + ρ)) worth of accumulated update mass overall;
        // check the second decode sees the momentum-boosted value
        let mut sk = SegmentSketcher::new(3, 256, 1);
        let ids: Vec<u64> = (0..4).collect();
        let vals = vec![2.0f32, 0.0, 0.0, 0.0];
        let mut wire = vec![0.0f32; sk.sketch_len()];
        sk.encode(&ids, &vals, &mut wire);
        let (mut out_ids, mut out_vals) = (Vec::new(), Vec::new());
        // step 1: M = 2, E = 2 → recover 2, zero cells
        sk.decode(&wire, 0.5, &ids, 1, &mut out_ids, &mut out_vals);
        assert_eq!(out_ids, vec![0]);
        assert!((out_vals[0] - 2.0).abs() < 1e-5);
        // step 2: M = 0.5·2 + 2 = 3, E = 0 + 3 → recover 3
        sk.decode(&wire, 0.5, &ids, 1, &mut out_ids, &mut out_vals);
        assert_eq!(out_ids, vec![0]);
        assert!((out_vals[0] - 3.0).abs() < 1e-5);
    }

    #[test]
    fn decode_is_deterministic_and_rank_independent() {
        // two sketchers fed the identical aggregate evolve identically —
        // the replicated-state invariant every rank relies on
        let mk = || SegmentSketcher::new(2, 128, 99);
        let (mut a, mut b) = (mk(), mk());
        let ids: Vec<u64> = (0..96).collect();
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..5 {
            let vals: Vec<f32> = (0..96).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut wire = vec![0.0f32; a.sketch_len()];
            a.encode(&ids, &vals, &mut wire);
            let (mut ia, mut va) = (Vec::new(), Vec::new());
            let (mut ib, mut vb) = (Vec::new(), Vec::new());
            a.decode(&wire, 0.9, &ids, 8, &mut ia, &mut va);
            b.decode(&wire, 0.9, &ids, 8, &mut ib, &mut vb);
            assert_eq!(ia, ib);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&va), bits(&vb));
            assert_eq!(bits(a.error_sketch()), bits(b.error_sketch()));
        }
    }

    #[test]
    fn segment_width_caps_to_half_the_dense_length() {
        // small segments cap: depth 3 over a 512-coordinate bias segment
        assert_eq!(segment_width(1024, 3, 512), 85); // 512 / 6
        // large segments keep the configured width
        assert_eq!(segment_width(1024, 3, 26912), 1024);
        // degenerate segments never reach width 0
        assert_eq!(segment_width(1024, 4, 3), 1);
    }

    #[test]
    fn grad_sketcher_builds_decorrelated_segments() {
        let cfg = GradSketchCfg { depth: 3, width: 64, k: 8, momentum: 0.9, seed: 42 };
        let gs = GradSketcher::new(cfg, &[16384, 16384, 512, 26912]);
        assert_eq!(gs.segs.len(), 4);
        assert_eq!(gs.segs[0].width(), 64);
        assert_eq!(gs.segs[2].width(), 64); // 512/6 = 85 ≥ 64
        assert_eq!(gs.sketch_len(), 4 * 3 * 64);
        // same id must land in different buckets across segments (w.h.p.)
        let p0 = gs.segs[0].plan_for(&[0, 1, 2, 3, 4, 5, 6, 7]);
        let p1 = gs.segs[1].plan_for(&[0, 1, 2, 3, 4, 5, 6, 7]);
        assert_ne!(p0.idx(), p1.idx());
    }
}
