//! Cross-process communication for width-partitioned sketch state
//! (DESIGN.md §9).
//!
//! A distributed run replicates the model and data pipeline in every
//! rank (they are deterministic, so replicas stay bit-identical for
//! free) and partitions only the **sketch state** — the memory the paper
//! is about. Because count-sketches are linear and each `[v, w, d]` cell
//! has exactly one owner under the width partition, the only collective
//! a QUERY needs is an **all-reduce by addition** of the gathered
//! per-(item, depth) bucket rows: every unowned contribution is an exact
//! `0.0`, so the sum reconstructs each row bit-for-bit and the
//! distributed run matches the single-process one exactly.
//!
//! * [`Transport`] — the collective surface ranks speak
//!   (`all_reduce_sum` + `barrier`).
//! * [`mem`] — in-memory impl for same-process multi-rank tests.
//! * [`uds`] — unix-domain-socket impl for real worker processes
//!   (length-prefixed frames with a JSON header, `util/json.rs`).
//! * [`partitioned`] — the [`SketchStore`](crate::sketch::SketchStore)
//!   impl owning one rank's width slice.
//! * [`DistCtx`] — rank + world + shared transport; the
//!   [`StoreBuilder`](crate::sketch::StoreBuilder) the trainer passes
//!   down so every sketch lands on a partitioned store.

pub mod mem;
pub mod partitioned;
#[cfg(unix)]
pub mod uds;

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::sketch::{SketchStore, StoreBuilder};

pub use mem::{mem_world, MemComm};
pub use partitioned::PartitionedStore;
#[cfg(unix)]
pub use uds::UdsTransport;

/// Collective operations between the ranks of one run.
///
/// Implementations synchronize by **call order**: every rank must issue
/// the same sequence of collectives with the same buffer lengths (the
/// training loop is identical in every rank, so this holds by
/// construction). `all_reduce_sum` accumulates contributions in rank
/// order, so the result is deterministic.
pub trait Transport: Send {
    fn rank(&self) -> usize;
    fn world(&self) -> usize;

    /// Elementwise sum of `buf` across all ranks; every rank's `buf`
    /// holds the reduced result on return.
    fn all_reduce_sum(&mut self, buf: &mut [f32]) -> Result<()>;

    /// Block until every rank reaches the barrier.
    fn barrier(&mut self) -> Result<()>;
}

/// One rank's view of a distributed run: identity plus the shared
/// transport every partitioned sketch store in this process reduces
/// over. All layers (embedding, softmax, CsAdam's m/v pair) share the
/// single connection; the deterministic step sequence keeps their
/// collectives aligned across ranks.
#[derive(Clone)]
pub struct DistCtx {
    pub rank: usize,
    pub world: usize,
    comm: Arc<Mutex<dyn Transport>>,
}

impl DistCtx {
    pub fn new<T: Transport + 'static>(rank: usize, world: usize, transport: T) -> DistCtx {
        DistCtx { rank, world, comm: Arc::new(Mutex::new(transport)) }
    }

    /// The shared transport handle.
    pub fn comm(&self) -> Arc<Mutex<dyn Transport>> {
        Arc::clone(&self.comm)
    }

    /// Run a barrier across all ranks (end-of-run synchronization).
    pub fn barrier(&self) -> Result<()> {
        self.comm.lock().unwrap().barrier()
    }
}

impl std::fmt::Debug for DistCtx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "DistCtx {{ rank: {}, world: {} }}", self.rank, self.world)
    }
}

impl StoreBuilder for DistCtx {
    fn build(&self, depth: usize, width: usize, dim: usize) -> Box<dyn SketchStore> {
        Box::new(PartitionedStore::new(depth, width, dim, self.rank, self.world, self.comm()))
    }
}
