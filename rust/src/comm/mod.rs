//! Cross-process communication for width-partitioned sketch state
//! (DESIGN.md §9).
//!
//! A distributed run replicates the model and data pipeline in every
//! rank (they are deterministic, so replicas stay bit-identical for
//! free) and partitions only the **sketch state** — the memory the paper
//! is about. Because count-sketches are linear and each `[v, w, d]` cell
//! has exactly one owner under the width partition, the only collective
//! a QUERY needs is an **all-reduce by addition** of the gathered
//! per-(item, depth) bucket rows: every unowned contribution is an exact
//! `0.0`, so the sum reconstructs each row bit-for-bit and the
//! distributed run matches the single-process one exactly.
//!
//! * [`Transport`] — the collective surface ranks speak:
//!   `all_reduce_sum` + `barrier`, plus the sparsity-aware trio
//!   (DESIGN.md §14) — `reduce_scatter_sum` / `all_gather` over the
//!   [`owned_span`] ownership map and `all_gather_rows` for sparse
//!   owned-rows frames. The trio has dense all-reduce fallbacks as
//!   default impls, so growing the trait broke no transport.
//! * [`mem`] — in-memory impl for same-process multi-rank tests.
//! * [`uds`] — unix-domain-socket impl for real worker processes
//!   (length-prefixed frames with a JSON header, `util/json.rs`).
//! * [`tcp`] — the same star topology over TCP for cross-host workers
//!   and the resident `serve` service; both socket transports share the
//!   frame codec in [`frame`] byte-for-byte and the generic star
//!   protocols in [`star`].
//! * [`overlap`] — [`CommPipe`], the dedicated comm thread that lets a
//!   trainer run step *t*'s gradient exchange while it prepares step
//!   *t+1* (`[dist] overlap = true`, DESIGN.md §14).
//! * [`partitioned`] — the [`SketchStore`](crate::sketch::SketchStore)
//!   impl owning one rank's width slice.
//! * [`DistCtx`] — rank + world + shared transport; the
//!   [`StoreBuilder`](crate::sketch::StoreBuilder) the trainer passes
//!   down so every sketch lands on a partitioned store.
//! * [`exchange_sum`] / [`average_replica_segments`] — the data-parallel
//!   gradient reduction (DESIGN.md §10): per-replica gradient segments
//!   all-reduced over the same transport, then averaged in replica
//!   order, so distinct-batch training composes with (or replaces) the
//!   sketch partition while staying bit-identical to the single-process
//!   global-batch run.

pub mod frame;
pub mod gradsketch;
pub mod mem;
pub mod overlap;
pub mod partitioned;
pub mod star;
pub mod tcp;
#[cfg(unix)]
pub mod uds;

use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::sketch::plan::width_partition;
use crate::sketch::{SketchStore, StoreBuilder};

pub use gradsketch::{GradSketchCfg, GradSketcher, SegmentSketcher};
pub use mem::{mem_world, MemComm};
pub use overlap::CommPipe;
pub use partitioned::PartitionedStore;
pub use tcp::TcpTransport;
#[cfg(unix)]
pub use uds::UdsTransport;

/// Collective operations between the ranks of one run.
///
/// Implementations synchronize by **call order**: every rank must issue
/// the same sequence of collectives with the same buffer lengths (the
/// training loop is identical in every rank, so this holds by
/// construction). `all_reduce_sum` accumulates contributions in rank
/// order, so the result is deterministic.
pub trait Transport: Send {
    fn rank(&self) -> usize;
    fn world(&self) -> usize;

    /// Elementwise sum of `buf` across all ranks; every rank's `buf`
    /// holds the reduced result on return.
    fn all_reduce_sum(&mut self, buf: &mut [f32]) -> Result<()>;

    /// Reduce-scatter by addition: the same rank-ordered elementwise sum
    /// as [`all_reduce_sum`](Transport::all_reduce_sum), but each rank
    /// is only guaranteed the reduced result over its **owned span** —
    /// the contiguous run of `granule`-sized chunks [`owned_span`]
    /// assigns it (the `width_partition` ownership map, so collective
    /// slices line up with partitioned sketch stores for free). Bytes
    /// outside the span are unspecified on return. Real transports ship
    /// each rank only its slice of the result; this default falls back
    /// to a full all-reduce (correct, dense), so the trait change is
    /// non-breaking for existing implementations.
    fn reduce_scatter_sum(&mut self, buf: &mut [f32], granule: usize) -> Result<()> {
        owned_span(buf.len(), granule, self.world(), self.rank())?;
        self.all_reduce_sum(buf)
    }

    /// All-gather over the same ownership map: on entry each rank's
    /// owned span (see [`owned_span`]) holds its contribution; on return
    /// the **whole** buffer is valid and bit-identical on every rank.
    /// Content outside the owned span on entry is ignored — the
    /// transport overwrites it with the other ranks' spans — so callers
    /// can hand in an un-zeroed scratch buffer. This default zeroes the
    /// unowned region and falls back to a full all-reduce (one owner
    /// per element, so the sum is an exact reconstruction, with the
    /// usual `-0.0 + 0.0 == +0.0` footnote).
    fn all_gather(&mut self, buf: &mut [f32], granule: usize) -> Result<()> {
        let (lo, hi) = owned_span(buf.len(), granule, self.world(), self.rank())?;
        buf[..lo].iter_mut().for_each(|x| *x = 0.0);
        buf[hi..].iter_mut().for_each(|x| *x = 0.0);
        self.all_reduce_sum(buf)
    }

    /// Gather sparse owned rows: each rank contributes a strictly
    /// ascending list of row `ids` (each `< id_space`) with a packed
    /// `[d]` payload per id; on return `out_ids` / `out_rows` hold the
    /// ascending union across all ranks, bit-identical on every rank.
    /// With `d > 0`, one id contributed by two ranks is a protocol error
    /// — disjoint ownership is exactly what makes the sparse exchange an
    /// exact reconstruction of the dense one. With `d == 0` the op is a
    /// pure id-set union (activity masks ride the frame header side of
    /// the wire, not the f32 payload) and duplicates merge silently.
    /// This default densifies into an `id_space × (1 + d)` indicator +
    /// payload buffer and all-reduces it — correct on any transport;
    /// only the real overrides are sparse on the wire.
    fn all_gather_rows(
        &mut self,
        ids: &[u64],
        rows: &[f32],
        d: usize,
        id_space: usize,
        out_ids: &mut Vec<u64>,
        out_rows: &mut Vec<f32>,
    ) -> Result<()> {
        validate_row_ids(ids, rows.len(), d, id_space)?;
        let mut dense = vec![0.0f32; id_space * (d + 1)];
        for (i, &id) in ids.iter().enumerate() {
            let base = id as usize * (d + 1);
            dense[base] = 1.0;
            dense[base + 1..base + 1 + d].copy_from_slice(&rows[i * d..(i + 1) * d]);
        }
        self.all_reduce_sum(&mut dense)?;
        out_ids.clear();
        out_rows.clear();
        for id in 0..id_space {
            let base = id * (d + 1);
            let hits = dense[base];
            if hits == 0.0 {
                continue;
            }
            if d > 0 && hits > 1.0 {
                bail!(
                    "row {id} was contributed by {hits} ranks — owned-rows frames \
                     require disjoint row ownership (or the ranks' op sequences diverged)"
                );
            }
            out_ids.push(id as u64);
            out_rows.extend_from_slice(&dense[base + 1..base + 1 + d]);
        }
        Ok(())
    }

    /// Block until every rank reaches the barrier.
    fn barrier(&mut self) -> Result<()>;

    /// Payload bytes this rank has pushed onto the wire so far (frames'
    /// f32 payloads plus headers where the transport has real frames).
    /// Dense-vs-sketched wire volume is a *measured* number through
    /// these, not a claim; the in-process default has no wire.
    fn bytes_sent(&self) -> u64 {
        0
    }

    /// Payload bytes this rank has pulled off the wire so far.
    fn bytes_received(&self) -> u64 {
        0
    }
}

/// One rank's view of a distributed run: identity plus the shared
/// transport every partitioned sketch store in this process reduces
/// over. All layers (embedding, softmax, CsAdam's m/v pair) share the
/// single connection; the deterministic step sequence keeps their
/// collectives aligned across ranks.
#[derive(Clone)]
pub struct DistCtx {
    pub rank: usize,
    pub world: usize,
    comm: Arc<Mutex<dyn Transport>>,
}

impl DistCtx {
    pub fn new<T: Transport + 'static>(rank: usize, world: usize, transport: T) -> DistCtx {
        DistCtx { rank, world, comm: Arc::new(Mutex::new(transport)) }
    }

    /// The shared transport handle.
    pub fn comm(&self) -> Arc<Mutex<dyn Transport>> {
        Arc::clone(&self.comm)
    }

    /// Run a barrier across all ranks (end-of-run synchronization).
    pub fn barrier(&self) -> Result<()> {
        self.comm.lock().unwrap().barrier()
    }
}

impl std::fmt::Debug for DistCtx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "DistCtx {{ rank: {}, world: {} }}", self.rank, self.world)
    }
}

impl StoreBuilder for DistCtx {
    fn build(&self, depth: usize, width: usize, dim: usize) -> Box<dyn SketchStore> {
        Box::new(PartitionedStore::new(depth, width, dim, self.rank, self.world, self.comm()))
    }
}

/// Complete a data-parallel gradient exchange (DESIGN.md §10): sum `buf`
/// element-wise across all ranks. Each rank contributes its own
/// replicas' segments and exact `0.0` everywhere else, so — exactly as
/// in the §9 width partition — the rank-ordered sum reconstructs every
/// segment bit-for-bit (one owner per element; the lone IEEE footnote is
/// `-0.0 + 0.0 == +0.0`, which compares equal everywhere downstream).
///
/// `comm = None` is the single-process global-batch layout: the buffer
/// already holds every replica's segment, so the exchange is the
/// identity. Routing both layouts through this helper is what makes
/// N-worker runs bitwise-equivalent to the 1-process reference.
pub fn exchange_sum(comm: Option<&Arc<Mutex<dyn Transport>>>, buf: &mut [f32]) -> Result<()> {
    if let Some(comm) = comm {
        comm.lock().unwrap().all_reduce_sum(buf)?;
    }
    Ok(())
}

/// [`exchange_sum`] over several buffers in **one** collective: packs
/// them back-to-back into `scratch`, all-reduces once, and unpacks —
/// one framed round-trip (one header, one handshake) instead of one per
/// buffer, which is what the per-step hot path wants when a mode
/// exchanges logically separate segments (comm-sketch's slot buffer +
/// activity masks; dense data mode could batch the same way). Buffer
/// *lengths* must agree across ranks, as with any collective; the
/// concatenation order is the caller's argument order, identical
/// everywhere by construction. `comm = None` is the identity.
pub fn exchange_sum_many(
    comm: Option<&Arc<Mutex<dyn Transport>>>,
    bufs: &mut [&mut [f32]],
    scratch: &mut Vec<f32>,
) -> Result<()> {
    let Some(comm) = comm else { return Ok(()) };
    scratch.clear();
    for buf in bufs.iter() {
        scratch.extend_from_slice(buf);
    }
    comm.lock().unwrap().all_reduce_sum(scratch)?;
    let mut off = 0usize;
    for buf in bufs.iter_mut() {
        buf.copy_from_slice(&scratch[off..off + buf.len()]);
        off += buf.len();
    }
    Ok(())
}

/// The contiguous element span of a `len`-f32 collective buffer (tiled
/// by `granule`-sized chunks) that `rank` of `world` owns under
/// [`width_partition`] — the same arithmetic the sketch width partition
/// and the replica stripes use, so every cell of every collective has
/// exactly one owner by construction. Errors when `len` is not a whole
/// number of granules; a rank may own an empty span when there are
/// fewer granules than ranks.
pub fn owned_span(len: usize, granule: usize, world: usize, rank: usize) -> Result<(usize, usize)> {
    if granule == 0 || len % granule != 0 {
        bail!(
            "collective buffer of {len} f32s is not a whole number of \
             granules of {granule} — the op's geometry is wrong"
        );
    }
    let (glo, ghi) = width_partition(len / granule, world, rank);
    Ok((glo * granule, ghi * granule))
}

/// Validate one owned-rows list before it goes near a wire (and after it
/// comes off one): ids strictly ascending — sorted with no duplicates —
/// every id inside `[0, id_space)`, and the packed payload exactly
/// `ids.len() * d` f32s. The codec and every transport run this, so a
/// malformed contribution surfaces as a contextual error instead of an
/// out-of-bounds reconstruction.
pub fn validate_row_ids(ids: &[u64], rows_len: usize, d: usize, id_space: usize) -> Result<()> {
    if rows_len != ids.len() * d {
        bail!(
            "owned-rows payload holds {rows_len} f32s for {} ids of d = {d} (want {})",
            ids.len(),
            ids.len() * d
        );
    }
    for (i, &id) in ids.iter().enumerate() {
        if id >= id_space as u64 {
            bail!("owned-rows id {id} is outside the id space of {id_space}");
        }
        if i > 0 && ids[i - 1] >= id {
            bail!(
                "owned-rows ids must be strictly ascending: id {id} at index {i} \
                 follows {}",
                ids[i - 1]
            );
        }
    }
    Ok(())
}

/// Merge two ascending owned-rows lists into one ascending union in
/// `out_ids` / `out_rows` (cleared first). Payload rows are **copied**,
/// never summed — each row has one owner, so there is nothing to reduce.
/// `d > 0` treats an id present in both lists as a protocol error;
/// `d == 0` (mask union) keeps one copy silently.
pub fn merge_owned_rows(
    a_ids: &[u64],
    a_rows: &[f32],
    b_ids: &[u64],
    b_rows: &[f32],
    d: usize,
    out_ids: &mut Vec<u64>,
    out_rows: &mut Vec<f32>,
) -> Result<()> {
    out_ids.clear();
    out_rows.clear();
    out_ids.reserve(a_ids.len() + b_ids.len());
    out_rows.reserve(a_rows.len() + b_rows.len());
    let (mut i, mut j) = (0usize, 0usize);
    while i < a_ids.len() || j < b_ids.len() {
        let take_a = match (a_ids.get(i), b_ids.get(j)) {
            (Some(&a), Some(&b)) if a == b => {
                if d > 0 {
                    bail!(
                        "row {a} appears in both ranks' owned-rows frames — ownership \
                         must be disjoint (or the ranks' op sequences diverged)"
                    );
                }
                j += 1; // mask union: keep one copy
                true
            }
            (Some(&a), Some(&b)) => a < b,
            (Some(_), None) => true,
            (None, _) => false,
        };
        if take_a {
            out_ids.push(a_ids[i]);
            out_rows.extend_from_slice(&a_rows[i * d..(i + 1) * d]);
            i += 1;
        } else {
            out_ids.push(b_ids[j]);
            out_rows.extend_from_slice(&b_rows[j * d..(j + 1) * d]);
            j += 1;
        }
    }
    Ok(())
}

/// Average the `replicas` equal `seg_len` segments of
/// `buf[.. replicas * seg_len]` element-wise into `out` (resized to
/// `seg_len`), accumulating **in replica order** — `(seg₀ + seg₁ + …) /
/// R`, the same order on every rank and in the single-process reference,
/// so the averaged global gradient is deterministic and bit-identical
/// across layouts (DESIGN.md §10: averaging, not summing, keeps the
/// effective step size independent of the replica count).
pub fn average_replica_segments(buf: &[f32], replicas: usize, seg_len: usize, out: &mut Vec<f32>) {
    assert!(replicas >= 1, "averaging over zero replicas");
    assert!(
        buf.len() >= replicas * seg_len,
        "exchange buffer holds {} f32s, {replicas} segments of {seg_len} need {}",
        buf.len(),
        replicas * seg_len
    );
    out.clear();
    out.extend_from_slice(&buf[..seg_len]);
    for r in 1..replicas {
        let seg = &buf[r * seg_len..(r + 1) * seg_len];
        for (acc, &x) in out.iter_mut().zip(seg) {
            *acc += x;
        }
    }
    let inv = replicas as f32;
    for x in out.iter_mut() {
        *x /= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn owned_span_tiles_the_buffer_exactly_once() {
        for world in [1usize, 2, 3, 5] {
            for (len, granule) in [(12usize, 3usize), (8, 4), (6, 6), (0, 2), (4, 2)] {
                let mut cover = 0usize;
                let mut expect_lo = 0usize;
                for rank in 0..world {
                    let (lo, hi) = owned_span(len, granule, world, rank).unwrap();
                    assert!(lo <= hi && hi <= len, "len={len} g={granule} w={world} r={rank}");
                    assert_eq!(lo % granule, 0);
                    assert_eq!(hi % granule, 0);
                    if lo < hi {
                        assert_eq!(lo, expect_lo, "spans must be contiguous in rank order");
                        expect_lo = hi;
                    }
                    cover += hi - lo;
                }
                assert_eq!(cover, len, "len={len} g={granule} w={world}");
            }
        }
        let e = owned_span(10, 3, 2, 0).unwrap_err();
        assert!(format!("{e:#}").contains("whole number of granules"), "{e:#}");
    }

    #[test]
    fn validate_row_ids_rejects_malformed_lists() {
        validate_row_ids(&[0, 3, 9], 6, 2, 10).unwrap();
        validate_row_ids(&[], 0, 4, 10).unwrap();
        let unsorted = validate_row_ids(&[3, 1], 4, 2, 10).unwrap_err();
        assert!(format!("{unsorted:#}").contains("strictly ascending"), "{unsorted:#}");
        let dup = validate_row_ids(&[3, 3], 4, 2, 10).unwrap_err();
        assert!(format!("{dup:#}").contains("strictly ascending"), "{dup:#}");
        let oob = validate_row_ids(&[10], 2, 2, 10).unwrap_err();
        assert!(format!("{oob:#}").contains("outside the id space"), "{oob:#}");
        let arity = validate_row_ids(&[1], 3, 2, 10).unwrap_err();
        assert!(format!("{arity:#}").contains("payload holds 3 f32s"), "{arity:#}");
    }

    #[test]
    fn merge_owned_rows_interleaves_and_guards_ownership() {
        let (mut ids, mut rows) = (Vec::new(), Vec::new());
        merge_owned_rows(
            &[1, 4],
            &[1.0, 1.5, 4.0, 4.5],
            &[0, 2, 7],
            &[0.0, 0.5, 2.0, 2.5, 7.0, 7.5],
            2,
            &mut ids,
            &mut rows,
        )
        .unwrap();
        assert_eq!(ids, vec![0, 1, 2, 4, 7]);
        assert_eq!(rows, vec![0.0, 0.5, 1.0, 1.5, 2.0, 2.5, 4.0, 4.5, 7.0, 7.5]);
        // d > 0: a shared id is a broken ownership invariant
        let e = merge_owned_rows(&[2], &[9.0], &[2], &[8.0], 1, &mut ids, &mut rows)
            .unwrap_err();
        assert!(format!("{e:#}").contains("ownership"), "{e:#}");
        // d == 0 is the mask union: duplicates collapse silently
        merge_owned_rows(&[1, 2, 5], &[], &[2, 3], &[], 0, &mut ids, &mut rows).unwrap();
        assert_eq!(ids, vec![1, 2, 3, 5]);
        assert!(rows.is_empty());
    }

    /// A transport that implements only the required methods: the
    /// default reduce-scatter / all-gather / gather-rows impls must fall
    /// back to all-reduce and still satisfy the ops' contracts — that is
    /// what makes the trait growth non-breaking.
    struct MinimalTransport(MemComm);

    impl Transport for MinimalTransport {
        fn rank(&self) -> usize {
            self.0.rank()
        }
        fn world(&self) -> usize {
            self.0.world()
        }
        fn all_reduce_sum(&mut self, buf: &mut [f32]) -> Result<()> {
            self.0.all_reduce_sum(buf)
        }
        fn barrier(&mut self) -> Result<()> {
            self.0.barrier()
        }
    }

    #[test]
    fn default_impls_fall_back_to_all_reduce() {
        let world = 3usize;
        let granule = 2usize;
        let len = 8usize; // 4 granules over 3 ranks: spans 2/1/1 granules
        type Out = ((usize, usize), Vec<f32>, Vec<f32>, Vec<u64>, Vec<f32>);
        let outs: Vec<Out> = thread::scope(|s| {
            let handles: Vec<_> = mem_world(world)
                .into_iter()
                .enumerate()
                .map(|(rank, ep)| {
                    s.spawn(move || {
                        let mut t = MinimalTransport(ep);
                        // reduce-scatter: contribution rank+1 everywhere
                        let mut rs = vec![(rank + 1) as f32; len];
                        t.reduce_scatter_sum(&mut rs, granule).unwrap();
                        let span = owned_span(len, granule, world, rank).unwrap();
                        // all-gather: own span holds rank-tagged values
                        let mut ag = vec![f32::NAN; len];
                        for x in &mut ag[span.0..span.1] {
                            *x = (10 * (rank + 1)) as f32;
                        }
                        t.all_gather(&mut ag, granule).unwrap();
                        // gather-rows: rank r owns row 2r with payload [r, -r]
                        let ids = [2 * rank as u64];
                        let rows = [rank as f32, -(rank as f32)];
                        let (mut oids, mut orows) = (Vec::new(), Vec::new());
                        t.all_gather_rows(&ids, &rows, 2, 8, &mut oids, &mut orows).unwrap();
                        (span, rs, ag, oids, orows)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (rank, (span, rs, ag, oids, orows)) in outs.iter().enumerate() {
            // sum of 1 + 2 + 3 = 6 over the owned span at least
            for x in &rs[span.0..span.1] {
                assert_eq!(*x, 6.0, "rank {rank}");
            }
            // the whole all-gather buffer is valid on every rank
            let mut expect = vec![0.0f32; len];
            for r in 0..world {
                let (lo, hi) = owned_span(len, granule, world, r).unwrap();
                for x in &mut expect[lo..hi] {
                    *x = (10 * (r + 1)) as f32;
                }
            }
            assert_eq!(ag, &expect, "rank {rank}");
            assert_eq!(oids, &vec![0u64, 2, 4], "rank {rank}");
            assert_eq!(orows, &vec![0.0, -0.0, 1.0, -1.0, 2.0, -2.0], "rank {rank}");
        }
    }

    #[test]
    fn average_accumulates_in_replica_order() {
        // 3 replicas × 2 elements; the mean is exact in f32 here
        let buf = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut out = Vec::new();
        average_replica_segments(&buf, 3, 2, &mut out);
        assert_eq!(out, vec![3.0, 4.0]);
        // one replica: identity
        average_replica_segments(&buf[..2], 1, 2, &mut out);
        assert_eq!(out, vec![1.0, 2.0]);
    }

    #[test]
    fn exchange_none_is_identity() {
        let mut buf = vec![1.5f32, -2.25, 0.0];
        let before = buf.clone();
        exchange_sum(None, &mut buf).unwrap();
        assert_eq!(buf, before);
        let mut a = vec![1.0f32, 2.0];
        let mut b = vec![3.0f32];
        let mut scratch = Vec::new();
        exchange_sum_many(None, &mut [&mut a, &mut b], &mut scratch).unwrap();
        assert_eq!((a, b), (vec![1.0, 2.0], vec![3.0]));
        assert!(scratch.is_empty());
    }

    /// Batching buffers into one collective must reduce each of them to
    /// the same bits as reducing them one by one — and count the same
    /// payload in ONE round-trip's worth of frames.
    #[test]
    fn exchange_many_matches_per_buffer_exchanges_bitwise() {
        let world = 3usize;
        let outs: Vec<(Vec<f32>, Vec<f32>, u64)> = thread::scope(|s| {
            let handles: Vec<_> = mem_world(world)
                .into_iter()
                .enumerate()
                .map(|(rank, ep)| {
                    s.spawn(move || {
                        let comm: Arc<Mutex<dyn Transport>> = Arc::new(Mutex::new(ep));
                        let mut a: Vec<f32> = (0..5).map(|i| (rank * 10 + i) as f32).collect();
                        let mut b: Vec<f32> = (0..3).map(|i| -((rank + i) as f32)).collect();
                        let mut scratch = Vec::new();
                        exchange_sum_many(Some(&comm), &mut [&mut a, &mut b], &mut scratch)
                            .unwrap();
                        let sent = comm.lock().unwrap().bytes_sent();
                        (a, b, sent)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // reference: per-buffer reduction over a fresh world
        let expect_a: Vec<f32> = (0..5).map(|i| (0..world).map(|r| (r * 10 + i) as f32).sum()).collect();
        let expect_b: Vec<f32> = (0..3).map(|i| -((0..world).map(|r| (r + i) as f32).sum::<f32>())).collect();
        for (rank, (a, b, sent)) in outs.iter().enumerate() {
            assert_eq!(a, &expect_a, "rank {rank}");
            assert_eq!(b, &expect_b, "rank {rank}");
            // one 8-element collective: 8 · 4 bytes counted once
            assert_eq!(*sent, 32, "rank {rank}");
        }
    }

    /// The §10 ownership argument at helper level: ranks holding disjoint
    /// segments (zeros elsewhere) exchange + average to the same bits as
    /// one process holding all segments locally.
    #[test]
    fn exchange_reconstructs_segments_bitwise() {
        let (replicas, seg_len) = (4usize, 5usize);
        let mut rng = crate::util::rng::Rng::new(0x5EC5);
        let full: Vec<f32> =
            (0..replicas * seg_len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut local_avg = Vec::new();
        average_replica_segments(&full, replicas, seg_len, &mut local_avg);

        for world in [1usize, 2, 4] {
            let outs: Vec<(Vec<f32>, Vec<f32>)> = thread::scope(|s| {
                let handles: Vec<_> = mem_world(world)
                    .into_iter()
                    .enumerate()
                    .map(|(rank, ep)| {
                        let full = full.clone();
                        s.spawn(move || {
                            let comm: Arc<Mutex<dyn Transport>> = Arc::new(Mutex::new(ep));
                            // rank owns replicas [lo, hi)
                            let per = replicas / world;
                            let (lo, hi) = (rank * per, (rank + 1) * per);
                            let mut buf = vec![0.0f32; replicas * seg_len];
                            buf[lo * seg_len..hi * seg_len]
                                .copy_from_slice(&full[lo * seg_len..hi * seg_len]);
                            exchange_sum(Some(&comm), &mut buf).unwrap();
                            let mut avg = Vec::new();
                            average_replica_segments(&buf, replicas, seg_len, &mut avg);
                            (buf, avg)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (rank, (buf, avg)) in outs.iter().enumerate() {
                for (i, (a, b)) in buf.iter().zip(&full).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "world={world} rank={rank} element {i}"
                    );
                }
                for (i, (a, b)) in avg.iter().zip(&local_avg).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "avg world={world} rank={rank} at {i}");
                }
            }
        }
    }
}
