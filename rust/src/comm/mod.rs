//! Cross-process communication for width-partitioned sketch state
//! (DESIGN.md §9).
//!
//! A distributed run replicates the model and data pipeline in every
//! rank (they are deterministic, so replicas stay bit-identical for
//! free) and partitions only the **sketch state** — the memory the paper
//! is about. Because count-sketches are linear and each `[v, w, d]` cell
//! has exactly one owner under the width partition, the only collective
//! a QUERY needs is an **all-reduce by addition** of the gathered
//! per-(item, depth) bucket rows: every unowned contribution is an exact
//! `0.0`, so the sum reconstructs each row bit-for-bit and the
//! distributed run matches the single-process one exactly.
//!
//! * [`Transport`] — the collective surface ranks speak
//!   (`all_reduce_sum` + `barrier`).
//! * [`mem`] — in-memory impl for same-process multi-rank tests.
//! * [`uds`] — unix-domain-socket impl for real worker processes
//!   (length-prefixed frames with a JSON header, `util/json.rs`).
//! * [`tcp`] — the same star topology over TCP for cross-host workers
//!   and the resident `serve` service; both socket transports share the
//!   frame codec in [`frame`] byte-for-byte.
//! * [`partitioned`] — the [`SketchStore`](crate::sketch::SketchStore)
//!   impl owning one rank's width slice.
//! * [`DistCtx`] — rank + world + shared transport; the
//!   [`StoreBuilder`](crate::sketch::StoreBuilder) the trainer passes
//!   down so every sketch lands on a partitioned store.
//! * [`exchange_sum`] / [`average_replica_segments`] — the data-parallel
//!   gradient reduction (DESIGN.md §10): per-replica gradient segments
//!   all-reduced over the same transport, then averaged in replica
//!   order, so distinct-batch training composes with (or replaces) the
//!   sketch partition while staying bit-identical to the single-process
//!   global-batch run.

pub mod frame;
pub mod gradsketch;
pub mod mem;
pub mod partitioned;
pub mod tcp;
#[cfg(unix)]
pub mod uds;

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::sketch::{SketchStore, StoreBuilder};

pub use gradsketch::{GradSketchCfg, GradSketcher, SegmentSketcher};
pub use mem::{mem_world, MemComm};
pub use partitioned::PartitionedStore;
pub use tcp::TcpTransport;
#[cfg(unix)]
pub use uds::UdsTransport;

/// Collective operations between the ranks of one run.
///
/// Implementations synchronize by **call order**: every rank must issue
/// the same sequence of collectives with the same buffer lengths (the
/// training loop is identical in every rank, so this holds by
/// construction). `all_reduce_sum` accumulates contributions in rank
/// order, so the result is deterministic.
pub trait Transport: Send {
    fn rank(&self) -> usize;
    fn world(&self) -> usize;

    /// Elementwise sum of `buf` across all ranks; every rank's `buf`
    /// holds the reduced result on return.
    fn all_reduce_sum(&mut self, buf: &mut [f32]) -> Result<()>;

    /// Block until every rank reaches the barrier.
    fn barrier(&mut self) -> Result<()>;

    /// Payload bytes this rank has pushed onto the wire so far (frames'
    /// f32 payloads plus headers where the transport has real frames).
    /// Dense-vs-sketched wire volume is a *measured* number through
    /// these, not a claim; the in-process default has no wire.
    fn bytes_sent(&self) -> u64 {
        0
    }

    /// Payload bytes this rank has pulled off the wire so far.
    fn bytes_received(&self) -> u64 {
        0
    }
}

/// One rank's view of a distributed run: identity plus the shared
/// transport every partitioned sketch store in this process reduces
/// over. All layers (embedding, softmax, CsAdam's m/v pair) share the
/// single connection; the deterministic step sequence keeps their
/// collectives aligned across ranks.
#[derive(Clone)]
pub struct DistCtx {
    pub rank: usize,
    pub world: usize,
    comm: Arc<Mutex<dyn Transport>>,
}

impl DistCtx {
    pub fn new<T: Transport + 'static>(rank: usize, world: usize, transport: T) -> DistCtx {
        DistCtx { rank, world, comm: Arc::new(Mutex::new(transport)) }
    }

    /// The shared transport handle.
    pub fn comm(&self) -> Arc<Mutex<dyn Transport>> {
        Arc::clone(&self.comm)
    }

    /// Run a barrier across all ranks (end-of-run synchronization).
    pub fn barrier(&self) -> Result<()> {
        self.comm.lock().unwrap().barrier()
    }
}

impl std::fmt::Debug for DistCtx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "DistCtx {{ rank: {}, world: {} }}", self.rank, self.world)
    }
}

impl StoreBuilder for DistCtx {
    fn build(&self, depth: usize, width: usize, dim: usize) -> Box<dyn SketchStore> {
        Box::new(PartitionedStore::new(depth, width, dim, self.rank, self.world, self.comm()))
    }
}

/// Complete a data-parallel gradient exchange (DESIGN.md §10): sum `buf`
/// element-wise across all ranks. Each rank contributes its own
/// replicas' segments and exact `0.0` everywhere else, so — exactly as
/// in the §9 width partition — the rank-ordered sum reconstructs every
/// segment bit-for-bit (one owner per element; the lone IEEE footnote is
/// `-0.0 + 0.0 == +0.0`, which compares equal everywhere downstream).
///
/// `comm = None` is the single-process global-batch layout: the buffer
/// already holds every replica's segment, so the exchange is the
/// identity. Routing both layouts through this helper is what makes
/// N-worker runs bitwise-equivalent to the 1-process reference.
pub fn exchange_sum(comm: Option<&Arc<Mutex<dyn Transport>>>, buf: &mut [f32]) -> Result<()> {
    if let Some(comm) = comm {
        comm.lock().unwrap().all_reduce_sum(buf)?;
    }
    Ok(())
}

/// [`exchange_sum`] over several buffers in **one** collective: packs
/// them back-to-back into `scratch`, all-reduces once, and unpacks —
/// one framed round-trip (one header, one handshake) instead of one per
/// buffer, which is what the per-step hot path wants when a mode
/// exchanges logically separate segments (comm-sketch's slot buffer +
/// activity masks; dense data mode could batch the same way). Buffer
/// *lengths* must agree across ranks, as with any collective; the
/// concatenation order is the caller's argument order, identical
/// everywhere by construction. `comm = None` is the identity.
pub fn exchange_sum_many(
    comm: Option<&Arc<Mutex<dyn Transport>>>,
    bufs: &mut [&mut [f32]],
    scratch: &mut Vec<f32>,
) -> Result<()> {
    let Some(comm) = comm else { return Ok(()) };
    scratch.clear();
    for buf in bufs.iter() {
        scratch.extend_from_slice(buf);
    }
    comm.lock().unwrap().all_reduce_sum(scratch)?;
    let mut off = 0usize;
    for buf in bufs.iter_mut() {
        buf.copy_from_slice(&scratch[off..off + buf.len()]);
        off += buf.len();
    }
    Ok(())
}

/// Average the `replicas` equal `seg_len` segments of
/// `buf[.. replicas * seg_len]` element-wise into `out` (resized to
/// `seg_len`), accumulating **in replica order** — `(seg₀ + seg₁ + …) /
/// R`, the same order on every rank and in the single-process reference,
/// so the averaged global gradient is deterministic and bit-identical
/// across layouts (DESIGN.md §10: averaging, not summing, keeps the
/// effective step size independent of the replica count).
pub fn average_replica_segments(buf: &[f32], replicas: usize, seg_len: usize, out: &mut Vec<f32>) {
    assert!(replicas >= 1, "averaging over zero replicas");
    assert!(
        buf.len() >= replicas * seg_len,
        "exchange buffer holds {} f32s, {replicas} segments of {seg_len} need {}",
        buf.len(),
        replicas * seg_len
    );
    out.clear();
    out.extend_from_slice(&buf[..seg_len]);
    for r in 1..replicas {
        let seg = &buf[r * seg_len..(r + 1) * seg_len];
        for (acc, &x) in out.iter_mut().zip(seg) {
            *acc += x;
        }
    }
    let inv = replicas as f32;
    for x in out.iter_mut() {
        *x /= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn average_accumulates_in_replica_order() {
        // 3 replicas × 2 elements; the mean is exact in f32 here
        let buf = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut out = Vec::new();
        average_replica_segments(&buf, 3, 2, &mut out);
        assert_eq!(out, vec![3.0, 4.0]);
        // one replica: identity
        average_replica_segments(&buf[..2], 1, 2, &mut out);
        assert_eq!(out, vec![1.0, 2.0]);
    }

    #[test]
    fn exchange_none_is_identity() {
        let mut buf = vec![1.5f32, -2.25, 0.0];
        let before = buf.clone();
        exchange_sum(None, &mut buf).unwrap();
        assert_eq!(buf, before);
        let mut a = vec![1.0f32, 2.0];
        let mut b = vec![3.0f32];
        let mut scratch = Vec::new();
        exchange_sum_many(None, &mut [&mut a, &mut b], &mut scratch).unwrap();
        assert_eq!((a, b), (vec![1.0, 2.0], vec![3.0]));
        assert!(scratch.is_empty());
    }

    /// Batching buffers into one collective must reduce each of them to
    /// the same bits as reducing them one by one — and count the same
    /// payload in ONE round-trip's worth of frames.
    #[test]
    fn exchange_many_matches_per_buffer_exchanges_bitwise() {
        let world = 3usize;
        let outs: Vec<(Vec<f32>, Vec<f32>, u64)> = thread::scope(|s| {
            let handles: Vec<_> = mem_world(world)
                .into_iter()
                .enumerate()
                .map(|(rank, ep)| {
                    s.spawn(move || {
                        let comm: Arc<Mutex<dyn Transport>> = Arc::new(Mutex::new(ep));
                        let mut a: Vec<f32> = (0..5).map(|i| (rank * 10 + i) as f32).collect();
                        let mut b: Vec<f32> = (0..3).map(|i| -((rank + i) as f32)).collect();
                        let mut scratch = Vec::new();
                        exchange_sum_many(Some(&comm), &mut [&mut a, &mut b], &mut scratch)
                            .unwrap();
                        let sent = comm.lock().unwrap().bytes_sent();
                        (a, b, sent)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // reference: per-buffer reduction over a fresh world
        let expect_a: Vec<f32> = (0..5).map(|i| (0..world).map(|r| (r * 10 + i) as f32).sum()).collect();
        let expect_b: Vec<f32> = (0..3).map(|i| -((0..world).map(|r| (r + i) as f32).sum::<f32>())).collect();
        for (rank, (a, b, sent)) in outs.iter().enumerate() {
            assert_eq!(a, &expect_a, "rank {rank}");
            assert_eq!(b, &expect_b, "rank {rank}");
            // one 8-element collective: 8 · 4 bytes counted once
            assert_eq!(*sent, 32, "rank {rank}");
        }
    }

    /// The §10 ownership argument at helper level: ranks holding disjoint
    /// segments (zeros elsewhere) exchange + average to the same bits as
    /// one process holding all segments locally.
    #[test]
    fn exchange_reconstructs_segments_bitwise() {
        let (replicas, seg_len) = (4usize, 5usize);
        let mut rng = crate::util::rng::Rng::new(0x5EC5);
        let full: Vec<f32> =
            (0..replicas * seg_len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut local_avg = Vec::new();
        average_replica_segments(&full, replicas, seg_len, &mut local_avg);

        for world in [1usize, 2, 4] {
            let outs: Vec<(Vec<f32>, Vec<f32>)> = thread::scope(|s| {
                let handles: Vec<_> = mem_world(world)
                    .into_iter()
                    .enumerate()
                    .map(|(rank, ep)| {
                        let full = full.clone();
                        s.spawn(move || {
                            let comm: Arc<Mutex<dyn Transport>> = Arc::new(Mutex::new(ep));
                            // rank owns replicas [lo, hi)
                            let per = replicas / world;
                            let (lo, hi) = (rank * per, (rank + 1) * per);
                            let mut buf = vec![0.0f32; replicas * seg_len];
                            buf[lo * seg_len..hi * seg_len]
                                .copy_from_slice(&full[lo * seg_len..hi * seg_len]);
                            exchange_sum(Some(&comm), &mut buf).unwrap();
                            let mut avg = Vec::new();
                            average_replica_segments(&buf, replicas, seg_len, &mut avg);
                            (buf, avg)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (rank, (buf, avg)) in outs.iter().enumerate() {
                for (i, (a, b)) in buf.iter().zip(&full).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "world={world} rank={rank} element {i}"
                    );
                }
                for (i, (a, b)) in avg.iter().zip(&local_avg).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "avg world={world} rank={rank} at {i}");
                }
            }
        }
    }
}
