//! [`CommPipe`] — a dedicated communication thread for compute/comm
//! overlap (DESIGN.md §14, the `[dist] overlap = true` knob).
//!
//! The pipe owns one worker thread draining a FIFO job queue: `submit`
//! hands it a closure (typically "run step t's whole gradient
//! exchange"), returns a [`Ticket`] immediately, and the caller goes on
//! preparing step t+1's *weight-independent* work — batch fetch, plan
//! construction, candidate sampling — while the collective crosses the
//! wire. `Ticket::wait` blocks until the closure's result is ready.
//!
//! Determinism survives because ordering is preserved at both ends: the
//! single worker thread runs jobs strictly in submission order, so this
//! rank's collectives hit the transport in the same sequence the
//! synchronous path would issue them, and the caller consumes each
//! ticket before it uses any value the exchange produced. Overlap moves
//! *when* the wait happens, never *what* is computed — the synchronous
//! path is the bitwise reference, and the equivalence suites hold the
//! overlapped path to it.

use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Handle to an in-flight job; `wait` joins it.
pub struct Ticket<T> {
    rx: mpsc::Receiver<Result<T>>,
}

impl<T> Ticket<T> {
    /// Block until the job finishes and return its result. A dead comm
    /// thread (panicked job) surfaces as an error, not a hang.
    pub fn wait(self) -> Result<T> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("comm thread died before delivering its result"))?
    }
}

/// One comm thread + FIFO queue; dropping the pipe drains outstanding
/// jobs and joins the thread.
pub struct CommPipe {
    tx: Option<mpsc::Sender<Job>>,
    handle: Option<JoinHandle<()>>,
}

impl CommPipe {
    pub fn new() -> CommPipe {
        let (tx, rx) = mpsc::channel::<Job>();
        let handle = std::thread::Builder::new()
            .name("csopt-comm".into())
            .spawn(move || {
                for job in rx {
                    job();
                }
            })
            .expect("spawning comm thread");
        CommPipe { tx: Some(tx), handle: Some(handle) }
    }

    /// Queue `job` on the comm thread; jobs run strictly in submission
    /// order. The closure moves its buffers in and hands them back
    /// through the result, so no aliasing with the preparing step.
    pub fn submit<T, F>(&self, job: F) -> Ticket<T>
    where
        T: Send + 'static,
        F: FnOnce() -> Result<T> + Send + 'static,
    {
        let (tx_r, rx_r) = mpsc::channel();
        let boxed: Job = Box::new(move || {
            // a dropped ticket is fine — send's error just discards
            let _ = tx_r.send(job());
        });
        self.tx
            .as_ref()
            .expect("CommPipe already shut down")
            .send(boxed)
            .expect("comm thread is gone");
        Ticket { rx: rx_r }
    }
}

impl Default for CommPipe {
    fn default() -> Self {
        CommPipe::new()
    }
}

impl Drop for CommPipe {
    fn drop(&mut self) {
        // closing the queue ends the worker's for-loop after it drains
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    /// Jobs run in submission order (the property collectives depend
    /// on) and results route back to the matching ticket.
    #[test]
    fn jobs_run_fifo_and_results_match() {
        let pipe = CommPipe::new();
        let seq = Arc::new(AtomicUsize::new(0));
        let tickets: Vec<_> = (0..16usize)
            .map(|i| {
                let seq = Arc::clone(&seq);
                pipe.submit(move || {
                    let turn = seq.fetch_add(1, Ordering::SeqCst);
                    anyhow::ensure!(turn == i, "job {i} ran at turn {turn}");
                    Ok(i * i)
                })
            })
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(t.wait().unwrap(), i * i);
        }
    }

    /// A job error comes back through the ticket; later jobs still run.
    #[test]
    fn errors_are_delivered_not_fatal() {
        let pipe = CommPipe::new();
        let bad = pipe.submit(|| -> Result<()> { anyhow::bail!("wire fell over") });
        let good = pipe.submit(|| Ok(7usize));
        assert!(format!("{:#}", bad.wait().unwrap_err()).contains("wire fell over"));
        assert_eq!(good.wait().unwrap(), 7);
    }

    /// Dropping the pipe with an unconsumed ticket neither hangs nor
    /// leaks the worker.
    #[test]
    fn drop_drains_and_joins() {
        let pipe = CommPipe::new();
        let _unwaited = pipe.submit(|| Ok(1usize));
        drop(pipe);
    }
}
