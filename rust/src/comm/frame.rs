//! Length-prefixed frame codec shared by every socket transport
//! (DESIGN.md §9/§13).
//!
//! Wire format (little-endian), one frame per message:
//!
//! ```text
//! u32 header_len | header (JSON, util/json.rs) | payload (header.n × f32)
//! ```
//!
//! The header is a small JSON object — `{"op":"allreduce","n":1024}`,
//! `{"op":"barrier","n":0}`, `{"op":"hello","rank":2,"world":4,"n":0}` —
//! parsed with the crate's own [`Json`]; the payload is raw f32 bytes
//! (JSON-encoding megabytes of floats would be slow and lossy).
//!
//! Extracted from the unix-socket transport so [`super::uds`] and
//! [`super::tcp`] (and the `serve` read path) speak byte-identical
//! frames: the functions are generic over [`Read`]/[`Write`], so a
//! `UnixStream`, a `TcpStream` and an in-memory buffer all round-trip
//! through the same code. The defensive bounds — the header-length
//! sanity cap and the caller-supplied `max_n` payload bound — are part
//! of the codec, not the transport: a desynced or corrupt peer must
//! surface as a diagnosable error on every wire, never as a giant
//! allocation or a hang.

use std::io::{Read, Write};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{num, obj, s, Json};

/// Write one frame; returns the frame's full byte count
/// (`4 + header + payload`).
pub fn write_frame<W: Write + ?Sized>(
    stream: &mut W,
    op: &str,
    extra: Vec<(&str, Json)>,
    payload: &[f32],
) -> Result<usize> {
    let mut fields = vec![("op", s(op)), ("n", num(payload.len() as f64))];
    fields.extend(extra);
    let header = obj(fields).to_string();
    stream.write_all(&(header.len() as u32).to_le_bytes())?;
    stream.write_all(header.as_bytes())?;
    if !payload.is_empty() {
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(payload.as_ptr() as *const u8, payload.len() * 4)
        };
        stream.write_all(bytes)?;
    }
    stream.flush()?;
    Ok(4 + header.len() + payload.len() * 4)
}

/// Read one frame; the payload lands in `payload` (resized to header.n)
/// and the header comes back with the frame's full byte count.
/// `max_n` bounds the wire-supplied element count — a desynced or
/// corrupt peer must surface as the diagnosable divergence error below,
/// not as a giant allocation.
pub fn read_frame<R: Read + ?Sized>(
    stream: &mut R,
    payload: &mut Vec<f32>,
    max_n: usize,
) -> Result<(Json, usize)> {
    let mut len4 = [0u8; 4];
    stream.read_exact(&mut len4).context("reading frame header length")?;
    let hlen = u32::from_le_bytes(len4) as usize;
    if hlen > 1 << 16 {
        bail!("implausible frame header length {hlen}");
    }
    let mut hbuf = vec![0u8; hlen];
    stream.read_exact(&mut hbuf).context("reading frame header")?;
    let header = Json::parse(std::str::from_utf8(&hbuf)?)
        .context("parsing frame header JSON")?;
    let n = header.req("n")?.as_usize().ok_or_else(|| anyhow!("frame header n not a number"))?;
    if n > max_n {
        bail!(
            "frame payload of {n} f32s exceeds the expected {max_n} — the peer's op \
             sequence diverged (or the stream is corrupt)"
        );
    }
    payload.resize(n, 0.0);
    if n > 0 {
        let bytes: &mut [u8] = unsafe {
            std::slice::from_raw_parts_mut(payload.as_mut_ptr() as *mut u8, n * 4)
        };
        stream.read_exact(bytes).context("reading frame payload")?;
    }
    Ok((header, 4 + hlen + n * 4))
}

/// The `op` field of a frame header.
pub fn frame_op(header: &Json) -> Result<String> {
    Ok(header
        .req("op")?
        .as_str()
        .ok_or_else(|| anyhow!("frame header op not a string"))?
        .to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    /// The codec is generic — an in-memory byte buffer exercises the
    /// identical code a UnixStream or TcpStream runs, including the
    /// denormal/sign-of-zero payload bit preservation.
    #[test]
    fn frame_roundtrip_preserves_bits() {
        let payload = vec![1.5f32, -0.0, f32::MIN_POSITIVE, 3.25e-40];
        let mut wire = Vec::new();
        let wrote =
            write_frame(&mut wire, "allreduce", vec![("tag", num(7.0))], &payload).unwrap();
        assert_eq!(wrote, wire.len());
        let mut cursor = Cursor::new(wire);
        let mut got = Vec::new();
        let (header, nbytes) = read_frame(&mut cursor, &mut got, 4).unwrap();
        assert_eq!(nbytes, wrote);
        assert!(nbytes > 4 + 4 * 4, "frame bytes cover header + payload, got {nbytes}");
        assert_eq!(frame_op(&header).unwrap(), "allreduce");
        assert_eq!(header.req("tag").unwrap().as_f64(), Some(7.0));
        assert_eq!(got.len(), 4);
        for (a, b) in got.iter().zip(payload.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn oversized_payload_is_rejected_before_allocation() {
        let mut wire = Vec::new();
        write_frame(&mut wire, "allreduce", vec![], &[1.0f32; 16]).unwrap();
        let mut got = Vec::new();
        let e = read_frame(&mut Cursor::new(wire), &mut got, 4).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("exceeds the expected 4"), "{msg}");
        assert!(msg.contains("diverged"), "{msg}");
    }

    #[test]
    fn implausible_header_length_is_rejected() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&u32::MAX.to_le_bytes());
        wire.extend_from_slice(&[0u8; 64]);
        let mut got = Vec::new();
        let e = read_frame(&mut Cursor::new(wire), &mut got, 0).unwrap_err();
        assert!(format!("{e:#}").contains("implausible frame header length"), "{e:#}");
    }
}
