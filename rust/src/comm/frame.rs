//! Length-prefixed frame codec shared by every socket transport
//! (DESIGN.md §9/§13).
//!
//! Wire format (little-endian), one frame per message:
//!
//! ```text
//! u32 header_len | header (JSON, util/json.rs) | payload (header.n × f32)
//! ```
//!
//! The header is a small JSON object — `{"op":"allreduce","n":1024}`,
//! `{"op":"barrier","n":0}`, `{"op":"hello","rank":2,"world":4,"n":0}` —
//! parsed with the crate's own [`Json`]; the payload is raw f32 bytes
//! (JSON-encoding megabytes of floats would be slow and lossy).
//!
//! Extracted from the unix-socket transport so [`super::uds`] and
//! [`super::tcp`] (and the `serve` read path) speak byte-identical
//! frames: the functions are generic over [`Read`]/[`Write`], so a
//! `UnixStream`, a `TcpStream` and an in-memory buffer all round-trip
//! through the same code. The defensive bounds — the header-length
//! sanity cap and the caller-supplied `max_n` payload bound — are part
//! of the codec, not the transport: a desynced or corrupt peer must
//! surface as a diagnosable error on every wire, never as a giant
//! allocation or a hang.

use std::io::{Read, Write};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{num, obj, s, Json};

/// Write one frame; returns the frame's full byte count
/// (`4 + header + payload`).
pub fn write_frame<W: Write + ?Sized>(
    stream: &mut W,
    op: &str,
    extra: Vec<(&str, Json)>,
    payload: &[f32],
) -> Result<usize> {
    let mut fields = vec![("op", s(op)), ("n", num(payload.len() as f64))];
    fields.extend(extra);
    let header = obj(fields).to_string();
    stream.write_all(&(header.len() as u32).to_le_bytes())?;
    stream.write_all(header.as_bytes())?;
    if !payload.is_empty() {
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(payload.as_ptr() as *const u8, payload.len() * 4)
        };
        stream.write_all(bytes)?;
    }
    stream.flush()?;
    Ok(4 + header.len() + payload.len() * 4)
}

/// Read one frame; the payload lands in `payload` (resized to header.n)
/// and the header comes back with the frame's full byte count.
/// `max_n` bounds the wire-supplied element count — a desynced or
/// corrupt peer must surface as the diagnosable divergence error below,
/// not as a giant allocation.
pub fn read_frame<R: Read + ?Sized>(
    stream: &mut R,
    payload: &mut Vec<f32>,
    max_n: usize,
) -> Result<(Json, usize)> {
    let mut len4 = [0u8; 4];
    stream.read_exact(&mut len4).context("reading frame header length")?;
    let hlen = u32::from_le_bytes(len4) as usize;
    if hlen > 1 << 16 {
        bail!("implausible frame header length {hlen}");
    }
    let mut hbuf = vec![0u8; hlen];
    stream.read_exact(&mut hbuf).context("reading frame header")?;
    let header = Json::parse(std::str::from_utf8(&hbuf)?)
        .context("parsing frame header JSON")?;
    let n = header.req("n")?.as_usize().ok_or_else(|| anyhow!("frame header n not a number"))?;
    if n > max_n {
        bail!(
            "frame payload of {n} f32s exceeds the expected {max_n} — the peer's op \
             sequence diverged (or the stream is corrupt)"
        );
    }
    payload.resize(n, 0.0);
    if n > 0 {
        let bytes: &mut [u8] = unsafe {
            std::slice::from_raw_parts_mut(payload.as_mut_ptr() as *mut u8, n * 4)
        };
        stream.read_exact(bytes).context("reading frame payload")?;
    }
    Ok((header, 4 + hlen + n * 4))
}

/// Write one owned-rows frame — the sparse counterpart of
/// [`write_frame`] (DESIGN.md §14). The header carries the geometry
/// (`rows`, `d`, `total` = the id space) alongside the usual `op` / `n`;
/// the binary body is `rows × u64` little-endian row ids followed by the
/// packed `rows × d` f32 payload. Row ids ride the header side of the
/// frame, not the f32 payload — they are routing metadata, so they are
/// never summed, averaged, or mistaken for gradient bytes. Returns the
/// frame's full byte count.
pub fn write_rows_frame<W: Write + ?Sized>(
    stream: &mut W,
    op: &str,
    ids: &[u64],
    payload: &[f32],
    d: usize,
    id_space: usize,
) -> Result<usize> {
    super::validate_row_ids(ids, payload.len(), d, id_space)
        .with_context(|| format!("encoding {op} owned-rows frame"))?;
    let header = obj(vec![
        ("op", s(op)),
        ("n", num(payload.len() as f64)),
        ("rows", num(ids.len() as f64)),
        ("d", num(d as f64)),
        ("total", num(id_space as f64)),
    ])
    .to_string();
    stream.write_all(&(header.len() as u32).to_le_bytes())?;
    stream.write_all(header.as_bytes())?;
    let mut id_bytes = Vec::with_capacity(ids.len() * 8);
    for &id in ids {
        id_bytes.extend_from_slice(&id.to_le_bytes());
    }
    stream.write_all(&id_bytes)?;
    if !payload.is_empty() {
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(payload.as_ptr() as *const u8, payload.len() * 4)
        };
        stream.write_all(bytes)?;
    }
    stream.flush()?;
    Ok(4 + header.len() + ids.len() * 8 + payload.len() * 4)
}

/// Read one owned-rows frame written by [`write_rows_frame`]. The
/// defensive bounds mirror [`read_frame`]'s and add the sparse ones: the
/// header-length cap, a `max_rows` bound on the wire-supplied row count
/// (checked before any allocation), the geometry (`d`, `total`)
/// cross-checked against what this rank is running, and the id list
/// itself re-validated — strictly ascending, in-bounds — before the
/// payload is read. A corrupt or desynced peer surfaces as a contextual
/// error, never a giant allocation or an out-of-bounds reconstruction.
pub fn read_rows_frame<R: Read + ?Sized>(
    stream: &mut R,
    ids: &mut Vec<u64>,
    payload: &mut Vec<f32>,
    expect_d: usize,
    id_space: usize,
    max_rows: usize,
) -> Result<(Json, usize)> {
    let mut len4 = [0u8; 4];
    stream.read_exact(&mut len4).context("reading frame header length")?;
    let hlen = u32::from_le_bytes(len4) as usize;
    if hlen > 1 << 16 {
        bail!("implausible frame header length {hlen}");
    }
    let mut hbuf = vec![0u8; hlen];
    stream.read_exact(&mut hbuf).context("reading frame header")?;
    let header = Json::parse(std::str::from_utf8(&hbuf)?)
        .context("parsing frame header JSON")?;
    let rows = header
        .req("rows")?
        .as_usize()
        .ok_or_else(|| anyhow!("owned-rows frame header rows not a number"))?;
    if rows > max_rows {
        bail!(
            "owned-rows frame claims {rows} rows, more than the expected {max_rows} — \
             the peer's op sequence diverged (or the stream is corrupt)"
        );
    }
    let d = header.req("d")?.as_usize().ok_or_else(|| anyhow!("frame header d not a number"))?;
    let total =
        header.req("total")?.as_usize().ok_or_else(|| anyhow!("frame header total not a number"))?;
    if d != expect_d || total != id_space {
        bail!(
            "owned-rows frame geometry d = {d}, total = {total} does not match this \
             rank's d = {expect_d}, total = {id_space} — the ranks' op sequences diverged"
        );
    }
    let n = header.req("n")?.as_usize().ok_or_else(|| anyhow!("frame header n not a number"))?;
    if n != rows * d {
        bail!(
            "owned-rows frame header is inconsistent: n = {n} f32s for {rows} rows of \
             d = {d} (want {})",
            rows * d
        );
    }
    let mut id_bytes = vec![0u8; rows * 8];
    stream.read_exact(&mut id_bytes).context("reading owned-rows frame ids")?;
    ids.clear();
    ids.reserve(rows);
    for chunk in id_bytes.chunks_exact(8) {
        ids.push(u64::from_le_bytes(chunk.try_into().unwrap()));
    }
    super::validate_row_ids(ids, n, d, id_space).context("validating owned-rows frame ids")?;
    payload.resize(n, 0.0);
    if n > 0 {
        let bytes: &mut [u8] = unsafe {
            std::slice::from_raw_parts_mut(payload.as_mut_ptr() as *mut u8, n * 4)
        };
        stream.read_exact(bytes).context("reading frame payload")?;
    }
    Ok((header, 4 + hlen + rows * 8 + n * 4))
}

/// The `op` field of a frame header.
pub fn frame_op(header: &Json) -> Result<String> {
    Ok(header
        .req("op")?
        .as_str()
        .ok_or_else(|| anyhow!("frame header op not a string"))?
        .to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    /// The codec is generic — an in-memory byte buffer exercises the
    /// identical code a UnixStream or TcpStream runs, including the
    /// denormal/sign-of-zero payload bit preservation.
    #[test]
    fn frame_roundtrip_preserves_bits() {
        let payload = vec![1.5f32, -0.0, f32::MIN_POSITIVE, 3.25e-40];
        let mut wire = Vec::new();
        let wrote =
            write_frame(&mut wire, "allreduce", vec![("tag", num(7.0))], &payload).unwrap();
        assert_eq!(wrote, wire.len());
        let mut cursor = Cursor::new(wire);
        let mut got = Vec::new();
        let (header, nbytes) = read_frame(&mut cursor, &mut got, 4).unwrap();
        assert_eq!(nbytes, wrote);
        assert!(nbytes > 4 + 4 * 4, "frame bytes cover header + payload, got {nbytes}");
        assert_eq!(frame_op(&header).unwrap(), "allreduce");
        assert_eq!(header.req("tag").unwrap().as_f64(), Some(7.0));
        assert_eq!(got.len(), 4);
        for (a, b) in got.iter().zip(payload.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn oversized_payload_is_rejected_before_allocation() {
        let mut wire = Vec::new();
        write_frame(&mut wire, "allreduce", vec![], &[1.0f32; 16]).unwrap();
        let mut got = Vec::new();
        let e = read_frame(&mut Cursor::new(wire), &mut got, 4).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("exceeds the expected 4"), "{msg}");
        assert!(msg.contains("diverged"), "{msg}");
    }

    #[test]
    fn rows_frame_roundtrip_preserves_bits() {
        let ids = vec![3u64, 7, 41];
        let payload = vec![1.5f32, -0.0, f32::MIN_POSITIVE, 3.25e-40, -2.0, 0.0];
        let mut wire = Vec::new();
        let wrote = write_rows_frame(&mut wire, "gatherrows", &ids, &payload, 2, 64).unwrap();
        assert_eq!(wrote, wire.len());
        let mut got_ids = vec![99u64];
        let mut got = vec![f32::NAN];
        let (header, nbytes) =
            read_rows_frame(&mut Cursor::new(wire), &mut got_ids, &mut got, 2, 64, 64).unwrap();
        assert_eq!(nbytes, wrote);
        assert_eq!(frame_op(&header).unwrap(), "gatherrows");
        assert_eq!(got_ids, ids);
        assert_eq!(got.len(), payload.len());
        for (a, b) in got.iter().zip(payload.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    /// d = 0 frames carry pure id sets (the mask path): no payload
    /// bytes at all, ids still validated and round-tripped.
    #[test]
    fn rows_frame_supports_empty_payload_mask_sets() {
        let ids = vec![0u64, 2, 5, 1023];
        let mut wire = Vec::new();
        let wrote = write_rows_frame(&mut wire, "gatherrows", &ids, &[], 0, 1024).unwrap();
        let mut got_ids = Vec::new();
        let mut got = Vec::new();
        let (_, nbytes) =
            read_rows_frame(&mut Cursor::new(wire), &mut got_ids, &mut got, 0, 1024, 1024)
                .unwrap();
        assert_eq!(nbytes, wrote);
        assert_eq!(got_ids, ids);
        assert!(got.is_empty());
    }

    #[test]
    fn rows_frame_rejects_malformed_id_lists() {
        // The writer refuses to encode garbage in the first place...
        let e = write_rows_frame(&mut Vec::new(), "gatherrows", &[5u64, 2], &[0.0; 2], 1, 8)
            .unwrap_err();
        assert!(format!("{e:#}").contains("strictly ascending"), "{e:#}");
        // ...and the reader re-validates independently: hand-craft a
        // frame whose header lies about geometry or whose ids are bad.
        let craft = |ids: &[u64], n: usize, d: usize, total: usize| {
            let mut wire = Vec::new();
            let header = format!(
                "{{\"op\":\"gatherrows\",\"n\":{n},\"rows\":{},\"d\":{d},\"total\":{total}}}",
                ids.len()
            );
            wire.extend_from_slice(&(header.len() as u32).to_le_bytes());
            wire.extend_from_slice(header.as_bytes());
            for &id in ids {
                wire.extend_from_slice(&id.to_le_bytes());
            }
            wire.extend_from_slice(&vec![0u8; n * 4]);
            wire
        };
        let read = |wire: Vec<u8>, d: usize, total: usize, max_rows: usize| {
            let (mut ids, mut pay) = (Vec::new(), Vec::new());
            read_rows_frame(&mut Cursor::new(wire), &mut ids, &mut pay, d, total, max_rows)
                .unwrap_err()
        };
        // Duplicate ids.
        let e = read(craft(&[3, 3], 2, 1, 8), 1, 8, 8);
        assert!(format!("{e:#}").contains("strictly ascending"), "{e:#}");
        // Out-of-range id.
        let e = read(craft(&[3, 9], 2, 1, 8), 1, 8, 8);
        assert!(format!("{e:#}").contains("outside the id space"), "{e:#}");
        // Geometry mismatch vs what this rank runs.
        let e = read(craft(&[1, 2], 2, 1, 8), 4, 8, 8);
        assert!(format!("{e:#}").contains("op sequences diverged"), "{e:#}");
        // Row count beyond the cap — rejected before the id allocation.
        let e = read(craft(&[1, 2], 2, 1, 8), 1, 8, 1);
        assert!(format!("{e:#}").contains("more than the expected 1"), "{e:#}");
        // Inconsistent n vs rows·d.
        let e = read(craft(&[1, 2], 7, 1, 8), 1, 8, 8);
        assert!(format!("{e:#}").contains("inconsistent"), "{e:#}");
    }

    /// A frame that stops mid-ids (peer died) errors out instead of
    /// handing back a short read.
    #[test]
    fn truncated_rows_frame_errors_out() {
        let mut wire = Vec::new();
        write_rows_frame(&mut wire, "gatherrows", &[1u64, 2, 3], &[0.5; 3], 1, 8).unwrap();
        wire.truncate(wire.len() - 10);
        let (mut ids, mut pay) = (Vec::new(), Vec::new());
        let e = read_rows_frame(&mut Cursor::new(wire), &mut ids, &mut pay, 1, 8, 8).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("reading"), "{msg}");
    }

    #[test]
    fn implausible_header_length_is_rejected() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&u32::MAX.to_le_bytes());
        wire.extend_from_slice(&[0u8; 64]);
        let mut got = Vec::new();
        let e = read_frame(&mut Cursor::new(wire), &mut got, 0).unwrap_err();
        assert!(format!("{e:#}").contains("implausible frame header length"), "{e:#}");
    }
}
