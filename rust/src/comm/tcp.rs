//! TCP [`Transport`] — the unix-socket star topology over `TcpStream`,
//! so workers can live on other hosts (and the `serve` service loop can
//! span machines).
//!
//! Byte-identical wire format to [`super::uds`]: the shared codec in
//! [`super::frame`] writes `u32 header_len | JSON header | raw-f32
//! payload` frames, workers identify themselves with a `hello` frame,
//! and rank 0 accumulates collectives in rank order so every rank
//! receives bit-identical results. The only transport-specific pieces
//! are addressing (`host:port` instead of a filesystem path — `csopt`
//! dispatches on the `:`) and lifecycle: TCP has no socket file to go
//! stale, so [`TcpTransport::cleanup`] is a no-op kept for call-site
//! symmetry with the UDS transport.
//!
//! `TCP_NODELAY` is set on every stream: the collectives are strict
//! request/response ping-pong, exactly the pattern Nagle's algorithm
//! penalizes with a stalled small-frame tail.

use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::num;

use super::frame::{frame_op, read_frame, write_frame};
use super::Transport;

/// How long listen/connect/read/write wait before declaring a peer dead
/// (same horizon as the UDS transport; the serve loop shrinks it via
/// `heartbeat_ms` so worker loss is detected in seconds, not minutes).
const IO_TIMEOUT: Duration = Duration::from_secs(120);

/// One rank's endpoint of a TCP-backed world.
pub struct TcpTransport {
    rank: usize,
    world: usize,
    /// Rank 0: stream to rank `r` at `peers[r - 1]`. Workers: one stream
    /// to rank 0.
    peers: Vec<TcpStream>,
    scratch: Vec<f32>,
    /// Frame bytes written / read on this endpoint (headers + payloads),
    /// including the hello handshake — real wire volume, so the
    /// metrics-CSV transport columns stay truthful in service mode.
    sent: u64,
    received: u64,
}

impl TcpTransport {
    /// Rank 0: bind `addr` (`host:port`; `host:0` picks a free port —
    /// recover it with [`local_addr`](TcpTransport::bound_addr) before
    /// spawning workers) and wait for ranks `1..world` to connect and
    /// say hello.
    pub fn listen(addr: &str, world: usize) -> Result<TcpTransport> {
        TcpTransport::listen_with_timeout(addr, world, IO_TIMEOUT)
    }

    /// [`TcpTransport::listen`] with an explicit I/O timeout governing
    /// the handshake wait and every subsequent read/write. The
    /// fault-injection suite and the serve heartbeat both shrink it.
    pub fn listen_with_timeout(
        addr: &str,
        world: usize,
        timeout: Duration,
    ) -> Result<TcpTransport> {
        assert!(world >= 2, "a 1-process run needs no transport");
        // Retry the bind: after a crashed generation the old accepted
        // sockets can sit in TIME_WAIT on this port, and the serve
        // supervisor rebinds the same address on every restart.
        let deadline = Instant::now() + timeout;
        let listener = loop {
            match TcpListener::bind(addr) {
                Ok(l) => break l,
                Err(e)
                    if e.kind() == std::io::ErrorKind::AddrInUse
                        && Instant::now() < deadline =>
                {
                    std::thread::sleep(Duration::from_millis(50));
                }
                Err(e) => {
                    return Err(e)
                        .with_context(|| format!("binding coordinator address {addr}"))
                }
            }
        };
        Self::accept_world(&listener, addr, world, timeout)
    }

    /// Accept `world - 1` hellos on an already-bound listener. Split out
    /// so the serve loop can bind once and re-accept a fresh world after
    /// a membership change without racing another process for the port.
    pub fn accept_world(
        listener: &TcpListener,
        addr: &str,
        world: usize,
        timeout: Duration,
    ) -> Result<TcpTransport> {
        assert!(world >= 2, "a 1-process run needs no transport");
        let mut peers: Vec<Option<TcpStream>> = (1..world).map(|_| None).collect();
        let deadline = Instant::now() + timeout;
        let mut payload = Vec::new();
        let mut received = 0u64;
        // non-blocking accept loop bounds the wait, so a dead worker fails
        // the run instead of hanging it
        listener.set_nonblocking(true)?;
        for _ in 1..world {
            let mut stream = loop {
                match listener.accept() {
                    Ok((stream, _)) => break stream,
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        if Instant::now() > deadline {
                            bail!("timed out waiting for workers to connect to {addr}");
                        }
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(e) => return Err(e).context("accepting worker connection"),
                }
            };
            stream.set_nonblocking(false)?;
            stream.set_nodelay(true)?;
            stream.set_read_timeout(Some(timeout))?;
            stream.set_write_timeout(Some(timeout))?;
            let (header, nbytes) = read_frame(&mut stream, &mut payload, 0)?;
            received += nbytes as u64;
            if frame_op(&header)? != "hello" {
                bail!("worker spoke {header:?} before hello");
            }
            let rank = header.req("rank")?.as_usize().ok_or_else(|| anyhow!("bad hello rank"))?;
            let peer_world =
                header.req("world")?.as_usize().ok_or_else(|| anyhow!("bad hello world"))?;
            if peer_world != world {
                bail!("worker rank {rank} was launched for world {peer_world}, this is {world}");
            }
            if rank == 0 || rank >= world {
                bail!("hello from invalid rank {rank} (world {world})");
            }
            if peers[rank - 1].replace(stream).is_some() {
                bail!("two workers claimed rank {rank}");
            }
        }
        Ok(TcpTransport {
            rank: 0,
            world,
            peers: peers.into_iter().map(|p| p.unwrap()).collect(),
            scratch: Vec::new(),
            sent: 0,
            received,
        })
    }

    /// Ranks 1..world: connect to rank 0's address (retrying while it
    /// comes up) and say hello.
    pub fn connect(addr: &str, rank: usize, world: usize) -> Result<TcpTransport> {
        TcpTransport::connect_with_timeout(addr, rank, world, IO_TIMEOUT)
    }

    /// [`TcpTransport::connect`] with an explicit I/O timeout (see
    /// [`listen_with_timeout`](TcpTransport::listen_with_timeout)).
    pub fn connect_with_timeout(
        addr: &str,
        rank: usize,
        world: usize,
        timeout: Duration,
    ) -> Result<TcpTransport> {
        assert!(rank >= 1 && rank < world, "connect is for worker ranks (got {rank}/{world})");
        let deadline = Instant::now() + timeout;
        let mut stream = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() > deadline {
                        return Err(e).with_context(|| {
                            format!("rank {rank}: coordinator address {addr} never came up")
                        });
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        };
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(timeout))?;
        stream.set_write_timeout(Some(timeout))?;
        let hello = write_frame(
            &mut stream,
            "hello",
            vec![("rank", num(rank as f64)), ("world", num(world as f64))],
            &[],
        )?;
        Ok(TcpTransport {
            rank,
            world,
            peers: vec![stream],
            scratch: Vec::new(),
            sent: hello as u64,
            received: 0,
        })
    }

    fn collective(&mut self, op: &str, buf: &mut [f32]) -> Result<()> {
        let mut payload = std::mem::take(&mut self.scratch);
        let result = self.collective_inner(op, buf, &mut payload);
        self.scratch = payload;
        result
    }

    fn collective_inner(&mut self, op: &str, buf: &mut [f32], payload: &mut Vec<f32>) -> Result<()> {
        if self.rank == 0 {
            // accumulate in rank order: own partial is already in buf
            for r in 1..self.world {
                let stream = &mut self.peers[r - 1];
                let (header, nbytes) = read_frame(stream, payload, buf.len())
                    .with_context(|| format!("receiving {op} partial from rank {r}"))?;
                self.received += nbytes as u64;
                let got = frame_op(&header)?;
                if got != op || payload.len() != buf.len() {
                    bail!(
                        "rank {r} sent op {got:?} ({} f32s) while coordinator runs {op:?} \
                         ({} f32s) — the ranks' op sequences diverged",
                        payload.len(),
                        buf.len()
                    );
                }
                for (acc, &x) in buf.iter_mut().zip(payload.iter()) {
                    *acc += x;
                }
            }
            for r in 1..self.world {
                let nbytes = write_frame(&mut self.peers[r - 1], op, vec![], buf)
                    .with_context(|| format!("sending {op} result to rank {r}"))?;
                self.sent += nbytes as u64;
            }
        } else {
            let stream = &mut self.peers[0];
            let nbytes = write_frame(stream, op, vec![], buf)
                .with_context(|| format!("rank {}: sending {op} partial", self.rank))?;
            self.sent += nbytes as u64;
            let (header, nbytes) = read_frame(stream, payload, buf.len())
                .with_context(|| format!("rank {}: receiving {op} result", self.rank))?;
            self.received += nbytes as u64;
            let got = frame_op(&header)?;
            if got != op || payload.len() != buf.len() {
                bail!(
                    "rank {}: coordinator answered {op:?} with op {got:?} ({} f32s, wanted {})",
                    self.rank,
                    payload.len(),
                    buf.len()
                );
            }
            buf.copy_from_slice(payload);
        }
        Ok(())
    }

    /// No socket file to remove — kept so launch/serve call sites treat
    /// both transports uniformly.
    pub fn cleanup(_addr: &str) {}
}

impl Transport for TcpTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn all_reduce_sum(&mut self, buf: &mut [f32]) -> Result<()> {
        self.collective("allreduce", buf)
    }

    fn reduce_scatter_sum(&mut self, buf: &mut [f32], granule: usize) -> Result<()> {
        let mut payload = std::mem::take(&mut self.scratch);
        let result = super::star::reduce_scatter(
            self.rank,
            self.world,
            &mut self.peers,
            "reducescatter",
            buf,
            granule,
            &mut payload,
            &mut self.sent,
            &mut self.received,
        );
        self.scratch = payload;
        result
    }

    fn all_gather(&mut self, buf: &mut [f32], granule: usize) -> Result<()> {
        let mut payload = std::mem::take(&mut self.scratch);
        let result = super::star::all_gather(
            self.rank,
            self.world,
            &mut self.peers,
            "allgather",
            buf,
            granule,
            &mut payload,
            &mut self.sent,
            &mut self.received,
        );
        self.scratch = payload;
        result
    }

    fn all_gather_rows(
        &mut self,
        ids: &[u64],
        rows: &[f32],
        d: usize,
        id_space: usize,
        out_ids: &mut Vec<u64>,
        out_rows: &mut Vec<f32>,
    ) -> Result<()> {
        super::star::all_gather_rows(
            self.rank,
            self.world,
            &mut self.peers,
            "gatherrows",
            ids,
            rows,
            d,
            id_space,
            out_ids,
            out_rows,
            &mut self.sent,
            &mut self.received,
        )
    }

    fn barrier(&mut self) -> Result<()> {
        self.collective("barrier", &mut [])
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }

    fn bytes_received(&self) -> u64 {
        self.received
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn three_rank_all_reduce_over_tcp() {
        // port 0: the OS picks a free port; workers get the real address
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let world = 3usize;
        let outs: Vec<Vec<f32>> = thread::scope(|s| {
            let mut handles = Vec::new();
            for rank in 1..world {
                let a = addr.clone();
                handles.push(s.spawn(move || {
                    let mut t = TcpTransport::connect(&a, rank, world).unwrap();
                    let mut buf = vec![rank as f32; 5];
                    t.all_reduce_sum(&mut buf).unwrap();
                    t.barrier().unwrap();
                    // hello + partial + barrier out; result + barrier back
                    assert!(t.bytes_sent() > 5 * 4, "sent {}", t.bytes_sent());
                    assert!(t.bytes_received() > 5 * 4, "received {}", t.bytes_received());
                    buf
                }));
            }
            let mut t0 =
                TcpTransport::accept_world(&listener, &addr, world, Duration::from_secs(30))
                    .unwrap();
            let mut buf = vec![0.0f32; 5];
            t0.all_reduce_sum(&mut buf).unwrap();
            t0.barrier().unwrap();
            let mut outs = vec![buf];
            outs.extend(handles.into_iter().map(|h| h.join().unwrap()));
            outs
        });
        for out in outs {
            assert_eq!(out, vec![3.0f32; 5]);
        }
    }
}
