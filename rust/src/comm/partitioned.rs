//! [`PartitionedStore`] — one rank's width slice of a `[v, w, d]` sketch
//! (DESIGN.md §9).
//!
//! The width axis `[0, w)` is split into `world` contiguous balanced
//! ranges (`sketch::plan::width_partition`, the same arithmetic the §5
//! in-process shard tiling uses); rank `r` materializes only
//! `[v, hi−lo, d]` floats. **Ownership invariant:** bucket `(j, b)` lives
//! on exactly one rank — the one whose range contains `b` — for every
//! depth `j`.
//!
//! * UPDATE scans the whole plan in item order and applies only in-range
//!   buckets, so each owned cell sees the same additions in the same
//!   order as the single-process path: partition state is bit-identical
//!   to the matching slice of a local tensor.
//! * QUERY gathers a `[v, k, d]` buffer of the plan's bucket rows —
//!   owned rows copied, unowned rows exact `0.0` — and all-reduces it by
//!   addition over the shared [`Transport`]. One owner per cell means
//!   the sum reconstructs every row exactly, and the local
//!   median/min reduction (the same `store::median_rows` / min loop the
//!   local path runs) yields bit-identical estimates on every rank.
//!
//! "Exactly" carries one IEEE footnote: an owned cell holding `-0.0`
//! comes back as `+0.0` (`-0.0 + 0.0 == +0.0`). The two compare equal,
//! every downstream use (`x - ±0`, `±0 * s`, `sqrt(±0) + eps`, min/median
//! selection) is sign-of-zero-insensitive, and a zero can never become a
//! nonzero difference — so parameters, losses and checkpoints still
//! match the single-process run under numeric equality, which is what
//! the equivalence suite asserts.
//!
//! QUERY's exchange is a reduce-scatter + all-gather pair (DESIGN.md
//! §14) rather than one dense all-reduce: the gather buffer is laid out
//! item-major so item `t`'s `v` bucket rows form one contiguous
//! `[v·d]` granule, `reduce_scatter_sum` reconstructs each item's rows
//! on exactly one rank, that owner runs the depth reduction for its
//! items, and `all_gather` ships only the reduced `[k, d]` estimates
//! back — `world×` less downstream traffic than re-broadcasting the
//! whole `[v, k, d]` gather. Determinism is unchanged: the partial sums
//! accumulate in the same rank order an all-reduce uses, and the owner's
//! reduced bits are *copied* to every rank.

use std::cell::RefCell;
use std::sync::{Arc, Mutex};

use crate::sketch::plan::width_partition;
use crate::sketch::store::{axpy_sign, median_rows, min_into, Reduce, SketchStore};
use crate::sketch::tensor::scale_in_place;
use crate::sketch::{SketchPlan, SketchTensor};

use super::Transport;

/// One rank's width partition of a sketch tensor.
pub struct PartitionedStore {
    depth: usize,
    width: usize,
    dim: usize,
    /// Owned width range `[lo, hi)` (identical for every depth row).
    lo: usize,
    hi: usize,
    rank: usize,
    world: usize,
    /// `[depth, hi-lo, dim]` row-major slice of the conceptual tensor.
    data: Vec<f32>,
    comm: Arc<Mutex<dyn Transport>>,
    /// Reused `[v, k, d]` gather buffer for queries (the per-step hot
    /// path must not reallocate; `query` takes `&self`, hence the cell).
    gather: RefCell<Vec<f32>>,
    /// Reused `[k, d]` delta buffer for the `step_fused` fall-back
    /// decomposition (same no-realloc rule as `gather`).
    delta_scratch: Vec<f32>,
}

impl PartitionedStore {
    pub fn new(
        depth: usize,
        width: usize,
        dim: usize,
        rank: usize,
        world: usize,
        comm: Arc<Mutex<dyn Transport>>,
    ) -> PartitionedStore {
        assert!(depth >= 1 && width >= 1 && dim >= 1 && world >= 1 && rank < world);
        let (lo, hi) = width_partition(width, world, rank);
        PartitionedStore {
            depth,
            width,
            dim,
            lo,
            hi,
            rank,
            world,
            data: vec![0.0; depth * (hi - lo) * dim],
            comm,
            gather: RefCell::new(Vec::new()),
            delta_scratch: Vec::new(),
        }
    }

    /// The owned width range `[lo, hi)`.
    pub fn range(&self) -> (usize, usize) {
        (self.lo, self.hi)
    }

    /// Partition width (`hi - lo`).
    fn pw(&self) -> usize {
        self.hi - self.lo
    }

    /// Mutable owned row `(j, b)` (caller guarantees `lo ≤ b < hi`).
    #[inline(always)]
    fn row_mut(&mut self, j: usize, b: usize) -> &mut [f32] {
        debug_assert!(j < self.depth && b >= self.lo && b < self.hi);
        let off = (j * self.pw() + (b - self.lo)) * self.dim;
        &mut self.data[off..off + self.dim]
    }

    /// Owned row `(j, b)`.
    #[inline(always)]
    fn row(&self, j: usize, b: usize) -> &[f32] {
        debug_assert!(j < self.depth && b >= self.lo && b < self.hi);
        let off = (j * self.pw() + (b - self.lo)) * self.dim;
        &self.data[off..off + self.dim]
    }
}

impl std::fmt::Debug for PartitionedStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PartitionedStore {{ [{}, {}, {}], rank {}/{}, width range [{}, {}) }}",
            self.depth, self.width, self.dim, self.rank, self.world, self.lo, self.hi
        )
    }
}

impl SketchStore for PartitionedStore {
    fn depth(&self) -> usize {
        self.depth
    }

    fn width(&self) -> usize {
        self.width
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn memory_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    fn shards(&self) -> usize {
        1
    }

    fn set_shards(&mut self, _n: usize) {
        // the cross-process partition *is* the sharding; in-partition
        // parallel execution is the §Perf "next" seam
    }

    fn update(&mut self, plan: &SketchPlan, deltas: &[f32], signed: bool) {
        let d = self.dim;
        let (v, k) = (plan.depth(), plan.k());
        debug_assert_eq!(deltas.len(), k * d);
        let (lo, hi) = (self.lo, self.hi);
        for j in 0..v {
            for t in 0..k {
                let b = plan.bucket(j, t);
                if b < lo || b >= hi {
                    continue;
                }
                let s = if signed { plan.sign(j, t) } else { 1.0 };
                let delta = &deltas[t * d..(t + 1) * d];
                axpy_sign(self.row_mut(j, b), delta, s);
            }
        }
    }

    fn query(&self, plan: &SketchPlan, reduce: Reduce, out: &mut [f32]) {
        let d = self.dim;
        let (v, k) = (plan.depth(), plan.k());
        debug_assert_eq!(out.len(), k * d);
        // partial gather, *item-major*: row (j, t) at [(t·v + j)·d ..],
        // so item t's v depth rows form one contiguous [v·d] granule the
        // reduce-scatter can assign to a single owner. Unowned rows stay
        // exact 0.0 so the sum reconstructs them bit-for-bit.
        let mut gather = self.gather.borrow_mut();
        gather.clear();
        gather.resize(v * k * d, 0.0);
        for j in 0..v {
            for t in 0..k {
                let b = plan.bucket(j, t);
                if b >= self.lo && b < self.hi {
                    gather[(t * v + j) * d..(t * v + j + 1) * d].copy_from_slice(self.row(j, b));
                }
            }
        }
        // item t ∈ [tlo, thi) lands complete on this rank only — the
        // same balanced split the width partition uses
        let (tlo, thi) = width_partition(k, self.world, self.rank);
        self.comm
            .lock()
            .unwrap()
            .reduce_scatter_sum(&mut gather, v * d)
            .expect("sketch query reduce-scatter failed");
        // owned-items depth reduction — the same reducers the local
        // store runs, producing the same bits every rank *would* compute
        // from the same complete rows
        match reduce {
            Reduce::SignedMedian => {
                const INLINE: usize = 8;
                let mut inline_rows = [(0usize, 0.0f32); INLINE];
                let mut heap_rows: Vec<(usize, f32)> = Vec::new();
                let mut median_buf: Vec<f32> = if v > 3 { vec![0.0; v] } else { Vec::new() };
                for t in tlo..thi {
                    let dst = &mut out[t * d..(t + 1) * d];
                    if v <= INLINE {
                        for (j, slot) in inline_rows[..v].iter_mut().enumerate() {
                            *slot = (t * v + j, plan.sign(j, t));
                        }
                        median_rows(&gather, d, &inline_rows[..v], &mut median_buf, dst);
                    } else {
                        heap_rows.clear();
                        for j in 0..v {
                            heap_rows.push((t * v + j, plan.sign(j, t)));
                        }
                        median_rows(&gather, d, &heap_rows, &mut median_buf, dst);
                    }
                }
            }
            Reduce::Min => {
                for t in tlo..thi {
                    let dst = &mut out[t * d..(t + 1) * d];
                    dst.copy_from_slice(&gather[(t * v) * d..(t * v + 1) * d]);
                    for j in 1..v {
                        let off = (t * v + j) * d;
                        min_into(dst, &gather[off..off + d]);
                    }
                }
            }
        }
        // ship only the reduced [k, d] estimates — every rank receives
        // the owner's bits verbatim
        self.comm
            .lock()
            .unwrap()
            .all_gather(out, d)
            .expect("sketch query all-gather failed");
    }

    /// The fused kernel does not apply here — `step_fused` is the
    /// **unfused decomposition**, kept as this store's implementation on
    /// purpose (DESIGN.md §12): QUERY is a collective (`all_reduce_sum`
    /// over the shared transport), so every rank must finish the gather
    /// exchange before any rank knows the estimates its delta depends
    /// on, and again after the update. The fusion window therefore
    /// closes at each query — a single-rank pass cannot cross it without
    /// changing the wire protocol. Because the decomposition *is* the
    /// trait method's reference semantics, distributed runs stay
    /// bit-identical to local fused runs for free; only the `[k, d]`
    /// delta scratch is kept across calls so the per-step hot path does
    /// not reallocate.
    fn step_fused(
        &mut self,
        plan: &SketchPlan,
        reduce: Reduce,
        signed: bool,
        pre_query: bool,
        make_delta: &mut dyn FnMut(&[f32], &mut [f32]),
        est: &mut [f32],
    ) {
        let kd = plan.k() * self.dim;
        debug_assert_eq!(est.len(), kd);
        let mut delta = std::mem::take(&mut self.delta_scratch);
        delta.resize(kd, 0.0);
        if pre_query {
            self.query(plan, reduce, est);
        }
        make_delta(est, &mut delta);
        self.update(plan, &delta, signed);
        self.query(plan, reduce, est);
        self.delta_scratch = delta;
    }

    fn scale(&mut self, alpha: f32) {
        scale_in_place(&mut self.data, alpha);
    }

    fn reset(&mut self) {
        self.data.iter_mut().for_each(|x| *x = 0.0);
    }

    fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    fn tensor(&self) -> Option<&SketchTensor> {
        None
    }

    fn tensor_mut(&mut self) -> Option<&mut SketchTensor> {
        None
    }

    fn fold_half(&mut self) {
        panic!(
            "fold_half changes the hash family mid-run, which a width-partitioned \
             distributed sketch does not support — fold before launching, or run \
             single-process"
        );
    }

    /// **Collective**: every rank scatters its owned rows into a zeroed
    /// full `[v·w·d]` buffer and the buffers are summed. One owner per
    /// cell makes the sum an exact reconstruction (same argument as
    /// `query`, same IEEE sign-of-zero footnote). All ranks must call
    /// this in lockstep and all receive the identical full tensor.
    fn snapshot_full(&self) -> Vec<f32> {
        let d = self.dim;
        let mut full = vec![0.0f32; self.depth * self.width * d];
        for j in 0..self.depth {
            for b in self.lo..self.hi {
                full[(j * self.width + b) * d..(j * self.width + b + 1) * d]
                    .copy_from_slice(self.row(j, b));
            }
        }
        self.comm
            .lock()
            .unwrap()
            .all_reduce_sum(&mut full)
            .expect("sketch snapshot all-reduce failed");
        full
    }

    /// Rank-local: copy this rank's width slice out of the full buffer.
    /// Works for **any** partition layout, so a rank rejoining under a
    /// different `(lo, hi)` (changed world size after a membership
    /// event) restores the correct slice from the same snapshot.
    fn restore_full(&mut self, full: &[f32]) {
        let d = self.dim;
        assert_eq!(
            full.len(),
            self.depth * self.width * d,
            "restore_full: buffer geometry mismatch"
        );
        for j in 0..self.depth {
            for b in self.lo..self.hi {
                let src = &full[(j * self.width + b) * d..(j * self.width + b + 1) * d];
                self.row_mut(j, b).copy_from_slice(src);
            }
        }
    }

    fn clone_box(&self) -> Box<dyn SketchStore> {
        Box::new(PartitionedStore {
            depth: self.depth,
            width: self.width,
            dim: self.dim,
            lo: self.lo,
            hi: self.hi,
            rank: self.rank,
            world: self.world,
            data: self.data.clone(),
            comm: Arc::clone(&self.comm),
            gather: RefCell::new(Vec::new()),
            delta_scratch: Vec::new(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::mem::mem_world;
    use crate::sketch::store::LocalStore;
    use crate::sketch::SketchHasher;
    use crate::util::rng::Rng;
    use std::thread;

    /// Partitioned update/query across 1..4 mem-transport ranks must be
    /// bit-identical to a whole-tensor local store — the §9 ownership
    /// invariant at the store level.
    #[test]
    fn partitioned_matches_local_bitwise() {
        for world in [1usize, 2, 3, 4] {
            let (v, w, d, k) = (3usize, 37usize, 4usize, 24usize);
            let h = SketchHasher::new(v, w, 11);
            let mut rng = Rng::new(world as u64);
            let ids: Vec<u64> = (0..k).map(|_| rng.below(512) as u64).collect();
            let deltas: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let plan = SketchPlan::build(&h, &ids);

            let mut local = LocalStore::zeros(v, w, d);
            local.update(&plan, &deltas, true);
            let mut expect_med = vec![0.0f32; k * d];
            local.query(&plan, Reduce::SignedMedian, &mut expect_med);
            let mut expect_min = vec![0.0f32; k * d];
            local.query(&plan, Reduce::Min, &mut expect_min);

            let outs: Vec<(Vec<f32>, Vec<f32>)> = thread::scope(|s| {
                let handles: Vec<_> = mem_world(world)
                    .into_iter()
                    .enumerate()
                    .map(|(rank, ep)| {
                        let (plan, deltas) = (plan.clone(), deltas.clone());
                        s.spawn(move || {
                            let comm: Arc<Mutex<dyn Transport>> = Arc::new(Mutex::new(ep));
                            let mut store = PartitionedStore::new(v, w, d, rank, world, comm);
                            store.update(&plan, &deltas, true);
                            let mut med = vec![0.0f32; k * d];
                            store.query(&plan, Reduce::SignedMedian, &mut med);
                            let mut min = vec![0.0f32; k * d];
                            store.query(&plan, Reduce::Min, &mut min);
                            (med, min)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (rank, (med, min)) in outs.iter().enumerate() {
                assert_eq!(med, &expect_med, "median world={world} rank={rank}");
                assert_eq!(min, &expect_min, "min world={world} rank={rank}");
            }
        }
    }

    #[test]
    fn partition_memory_is_the_ranks_share() {
        let comm: Arc<Mutex<dyn Transport>> =
            Arc::new(Mutex::new(mem_world(1).pop().unwrap()));
        let full = LocalStore::zeros(3, 100, 8).memory_bytes();
        let part = PartitionedStore::new(3, 100, 8, 0, 4, Arc::clone(&comm));
        assert_eq!(part.memory_bytes(), full / 4);
        assert_eq!(part.range(), (0, 25));
    }

    /// `snapshot_full` reconstructs the identical full tensor on every
    /// rank (bit-equal to the local store's backing buffer), and
    /// `restore_full` under a *different* world size reproduces the same
    /// estimates — the layout independence the serve rejoin protocol
    /// rides on (DESIGN.md §13).
    #[test]
    fn snapshot_restores_across_partition_layouts() {
        let (v, w, d, k) = (3usize, 41usize, 3usize, 17usize);
        let h = SketchHasher::new(v, w, 23);
        let mut rng = Rng::new(7);
        let ids: Vec<u64> = (0..k).map(|_| rng.below(256) as u64).collect();
        let deltas: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let plan = SketchPlan::build(&h, &ids);

        let mut local = LocalStore::zeros(v, w, d);
        local.update(&plan, &deltas, true);
        let expect_full = local.snapshot_full();
        let mut expect_med = vec![0.0f32; k * d];
        local.query(&plan, Reduce::SignedMedian, &mut expect_med);

        // world=3 writes, snapshots
        let snaps: Vec<Vec<f32>> = thread::scope(|s| {
            let handles: Vec<_> = mem_world(3)
                .into_iter()
                .enumerate()
                .map(|(rank, ep)| {
                    let (plan, deltas) = (plan.clone(), deltas.clone());
                    s.spawn(move || {
                        let comm: Arc<Mutex<dyn Transport>> = Arc::new(Mutex::new(ep));
                        let mut store = PartitionedStore::new(v, w, d, rank, 3, comm);
                        store.update(&plan, &deltas, true);
                        store.snapshot_full()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (rank, snap) in snaps.iter().enumerate() {
            assert_eq!(snap, &expect_full, "snapshot rank={rank}");
        }

        // world=2 restores the same snapshot under a different layout
        let snap = snaps[0].clone();
        let outs: Vec<Vec<f32>> = thread::scope(|s| {
            let handles: Vec<_> = mem_world(2)
                .into_iter()
                .enumerate()
                .map(|(rank, ep)| {
                    let (plan, snap) = (plan.clone(), snap.clone());
                    s.spawn(move || {
                        let comm: Arc<Mutex<dyn Transport>> = Arc::new(Mutex::new(ep));
                        let mut store = PartitionedStore::new(v, w, d, rank, 2, comm);
                        store.restore_full(&snap);
                        let mut med = vec![0.0f32; k * d];
                        store.query(&plan, Reduce::SignedMedian, &mut med);
                        med
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (rank, med) in outs.iter().enumerate() {
            assert_eq!(med, &expect_med, "restored median rank={rank}");
        }
    }

    #[test]
    #[should_panic(expected = "fold_half")]
    fn fold_half_is_rejected() {
        let comm: Arc<Mutex<dyn Transport>> =
            Arc::new(Mutex::new(mem_world(1).pop().unwrap()));
        PartitionedStore::new(2, 8, 1, 0, 1, comm).fold_half();
    }
}
