//! End-to-end training-behaviour tests (pure-Rust engine; no artifacts
//! needed) plus property-style sweeps over the optimizer zoo.

use csopt::config::lm_preset;
use csopt::data::corpus::SyntheticCorpus;
use csopt::exp::common::corpus_for;
use csopt::optim::{OptimPolicy, OptimSpec};
use csopt::train::engine::RustLmEngine;
use csopt::train::trainer::{LmTrainer, TrainerOptions};
use csopt::util::rng::Rng;

fn trainer(emb: &str, sm: &str, lr: f32, seed: u64) -> LmTrainer {
    let preset = lm_preset("tiny").unwrap();
    let policy =
        OptimPolicy::pair(OptimSpec::parse(emb).unwrap(), OptimSpec::parse(sm).unwrap());
    let mut opts = TrainerOptions::with_policy(preset, policy, lr);
    opts.seed = seed;
    let mut rng = Rng::new(seed);
    LmTrainer::new(opts, Box::new(RustLmEngine::new(preset, &mut rng)), None).unwrap()
}

#[test]
fn every_optimizer_variant_reduces_loss() {
    let corpus = SyntheticCorpus::generate(512, 30_000, 1.05, 0.6, 3);
    let (train, _, _) = corpus.split(0.05, 0.05);
    let cases = [
        ("adam", 1e-3f32),
        ("cs-adam", 1e-3),
        ("csv-adam", 1e-3),
        ("nmf-adam", 1e-3),
        ("momentum", 0.2),
        ("cs-momentum", 0.2),
        ("adagrad", 0.1),
        ("cs-adagrad", 0.1),
        ("cs-adam-v", 1e-3),
    ];
    for (emb, lr) in cases {
        let sm = OptimSpec::parse(emb).unwrap().as_dense().to_string();
        let mut tr = trainer(emb, &sm, lr, 1);
        let first = tr.train_epoch(train, 30).unwrap().mean_loss;
        let second = tr.train_epoch(train, 30).unwrap().mean_loss;
        assert!(
            second < first,
            "{emb}: loss did not decrease ({first} -> {second})"
        );
    }
}

#[test]
fn sketch_uses_less_memory_dense_same_quality_tiny() {
    let corpus = SyntheticCorpus::generate(512, 40_000, 1.05, 0.6, 5);
    let (train, _, test) = corpus.split(0.05, 0.08);
    let mut dense = trainer("adam", "adam", 1e-3, 2);
    let mut sketch = trainer("cs-adam", "adam", 1e-3, 2);
    for _ in 0..2 {
        dense.train_epoch(train, 100).unwrap();
        sketch.train_epoch(train, 100).unwrap();
    }
    let pd = dense.eval_ppl(test, 8).unwrap();
    let ps = sketch.eval_ppl(test, 8).unwrap();
    // paper shape: CS within a few percent of dense
    assert!(ps < pd * 1.2, "sketch ppl {ps} vs dense {pd}");
    // tiny preset: [3, 103, 32] ×2 sketches vs [512, 32] ×2 dense states
    assert!(sketch.emb.opt.memory_bytes() < dense.emb.opt.memory_bytes());
}

#[test]
fn recurrent_state_carries_across_windows() {
    let corpus = SyntheticCorpus::generate(512, 10_000, 1.05, 0.9, 6);
    let (train, _, _) = corpus.split(0.05, 0.05);
    let mut tr = trainer("adam", "adam", 1e-3, 3);
    // strongly sequential corpus (q=0.9): training should push loss well
    // below the unigram entropy, which is only possible with context
    let unigram = corpus.unigram_entropy();
    let mut last = f64::INFINITY;
    for _ in 0..4 {
        last = tr.train_epoch(train, 60).unwrap().mean_loss;
    }
    assert!(
        last < unigram,
        "loss {last} did not beat unigram entropy {unigram}"
    );
}

#[test]
fn checkpoint_roundtrip_preserves_training_state() {
    use csopt::train::checkpoint::Checkpoint;
    let corpus = SyntheticCorpus::generate(512, 8_000, 1.05, 0.5, 7);
    let (train, _, test) = corpus.split(0.05, 0.08);
    let mut tr = trainer("adam", "adam", 1e-3, 4);
    tr.train_epoch(train, 20).unwrap();
    let ppl_before = tr.eval_ppl(test, 4).unwrap();

    let mut ck = Checkpoint::new();
    ck.set_scalar("step", tr.step as u64);
    ck.set_blob("emb", &tr.emb.params);
    ck.set_blob("sm", &tr.sm.params);
    ck.set_blob("smb", &tr.sm_bias.params);
    let mut flat = Vec::new();
    tr.engine.pack_flat(&mut flat);
    ck.set_blob("trunk", &flat);
    let path = std::env::temp_dir().join(format!("csopt_it_{}.ck", std::process::id()));
    ck.save(&path).unwrap();

    // restore into a fresh trainer
    let back = Checkpoint::load(&path).unwrap();
    let mut tr2 = trainer("adam", "adam", 1e-3, 999);
    tr2.emb.params.copy_from_slice(back.blob("emb").unwrap());
    tr2.sm.params.copy_from_slice(back.blob("sm").unwrap());
    tr2.sm_bias.params.copy_from_slice(back.blob("smb").unwrap());
    tr2.engine.unpack_flat(back.blob("trunk").unwrap());
    let ppl_after = tr2.eval_ppl(test, 4).unwrap();
    assert!(
        (ppl_before - ppl_after).abs() < 1e-6 * ppl_before.max(1.0),
        "{ppl_before} vs {ppl_after}"
    );
    let _ = std::fs::remove_file(path);
}

#[test]
fn plateau_schedule_reduces_lr_during_training() {
    use csopt::optim::LrSchedule;
    let preset = lm_preset("tiny").unwrap();
    let mut opts = TrainerOptions::new(preset, OptimSpec::parse("momentum").unwrap(), 0.0);
    opts.schedule = LrSchedule::plateau(1.0, 0.25, 1);
    let mut rng = Rng::new(11);
    let mut tr = LmTrainer::new(opts, Box::new(RustLmEngine::new(preset, &mut rng)), None).unwrap();
    // report non-improving metrics → lr must decay
    let lr0 = tr.opts.schedule.at(1);
    tr.report_metric(5.0);
    tr.report_metric(5.0);
    let lr1 = tr.opts.schedule.at(1);
    assert!(lr1 < lr0);
}

#[test]
fn cleaning_policy_threads_through_trainer() {
    let preset = lm_preset("tiny").unwrap();
    let corpus = corpus_for(&preset, 16, 9);
    let (train, _, _) = corpus.split(0.05, 0.05);
    let policy = OptimPolicy::pair(
        OptimSpec::parse("cs-adagrad@clean=0.5/5").unwrap(),
        OptimSpec::parse("adagrad").unwrap(),
    );
    let opts = TrainerOptions::with_policy(preset, policy, 0.1);
    let mut rng = Rng::new(12);
    let mut tr = LmTrainer::new(opts, Box::new(RustLmEngine::new(preset, &mut rng)), None).unwrap();
    let r = tr.train_epoch(train, 12).unwrap();
    assert!(r.mean_loss.is_finite());
}
