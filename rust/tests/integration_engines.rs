//! Cross-engine integration: the Rust LM engine and the AOT XLA LM graph
//! must agree on losses and gradients for identical inputs — this
//! validates the hand-written Rust backprop against JAX autodiff *and*
//! the AOT lowering chain in one shot.

use csopt::config::lm_preset;
use csopt::model::LmGrads;
use csopt::train::engine::{LmEngine, RustLmEngine, XlaLmEngine};
use csopt::util::rng::Rng;

mod common;
use common::runtime_or_skip as runtime;

#[test]
fn rust_and_xla_engines_agree_on_loss_and_grads() {
    let preset = lm_preset("tiny").unwrap();
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(0xAB);
    let mut rust_eng = RustLmEngine::new(preset, &mut rng);
    let mut rng2 = Rng::new(0xAB);
    let mut xla_eng = XlaLmEngine::new(preset, &rt, &mut rng2).unwrap();
    // identical trunk params by construction (same seed); verify
    let mut fa = Vec::new();
    let mut fb = Vec::new();
    rust_eng.pack_flat(&mut fa);
    xla_eng.pack_flat(&mut fb);
    assert_eq!(fa, fb);

    let p = preset;
    let mut data_rng = Rng::new(0xCD);
    let mut emb = vec![0.0f32; p.k * p.de];
    data_rng.fill_normal(&mut emb, 0.1);
    let mut sm = vec![0.0f32; p.nc * p.de];
    data_rng.fill_normal(&mut sm, 0.1);
    let smb = vec![0.0f32; p.nc];
    let xslot: Vec<i32> = (0..p.batch * p.bptt).map(|_| data_rng.below(p.k) as i32).collect();
    let ytgt: Vec<i32> = (0..p.batch * p.bptt).map(|_| data_rng.below(p.nc) as i32).collect();
    let h0 = vec![0.0f32; p.batch * p.hd];
    let c0 = vec![0.0f32; p.batch * p.hd];

    let mut ga = LmGrads::default();
    let mut gb = LmGrads::default();
    let oa = rust_eng.train_step(&emb, &sm, &smb, &xslot, &ytgt, &h0, &c0, &mut ga).unwrap();
    let ob = xla_eng.train_step(&emb, &sm, &smb, &xslot, &ytgt, &h0, &c0, &mut gb).unwrap();

    assert!(
        (oa.loss - ob.loss).abs() < 1e-4 * (1.0 + oa.loss.abs()),
        "loss: rust {} vs xla {}",
        oa.loss,
        ob.loss
    );
    let close = |a: &[f32], b: &[f32], name: &str, tol: f32| {
        assert_eq!(a.len(), b.len(), "{name} length");
        let mut worst = 0.0f32;
        for i in 0..a.len() {
            let d = (a[i] - b[i]).abs() / (1.0 + a[i].abs());
            if d > worst {
                worst = d;
            }
            assert!(d < tol, "{name}[{i}]: {} vs {} (rel {d})", a[i], b[i]);
        }
        eprintln!("{name}: worst rel diff {worst:.2e}");
    };
    close(&ga.d_emb_rows, &gb.d_emb_rows, "d_emb", 1e-3);
    close(&ga.d_w_ih, &gb.d_w_ih, "d_w_ih", 1e-3);
    close(&ga.d_w_hh, &gb.d_w_hh, "d_w_hh", 1e-3);
    close(&ga.d_b_g, &gb.d_b_g, "d_b_g", 1e-3);
    close(&ga.d_w_p, &gb.d_w_p, "d_w_p", 1e-3);
    close(&ga.d_b_p, &gb.d_b_p, "d_b_p", 1e-3);
    close(&ga.d_sm_rows, &gb.d_sm_rows, "d_sm", 1e-3);
    close(&ga.d_sm_bias, &gb.d_sm_bias, "d_sm_bias", 1e-3);
    close(&oa.h_t, &ob.h_t, "h_t", 1e-3);
    close(&oa.c_t, &ob.c_t, "c_t", 1e-3);
}

#[test]
fn engines_agree_over_short_training_run() {
    // Train with both engines on the same stream; losses must stay close
    // (compounding drift would expose any systematic mismatch).
    use csopt::exp::common::corpus_for;
    use csopt::optim::{OptimPolicy, OptimSpec};
    use csopt::train::trainer::{LmTrainer, TrainerOptions};

    let preset = lm_preset("tiny").unwrap();
    let corpus = corpus_for(&preset, 24, 0x77);
    let (train, _, _) = corpus.split(0.05, 0.05);
    let Some(rt) = runtime() else { return };

    let mk = |engine: &str| -> LmTrainer {
        let emb = OptimSpec::parse("cs-adam").unwrap();
        let mut opts =
            TrainerOptions::with_policy(preset, OptimPolicy::pair(emb, emb.as_dense()), 1e-3);
        opts.seed = 9;
        let mut rng = Rng::new(9);
        let eng: Box<dyn LmEngine> = if engine == "rust" {
            Box::new(RustLmEngine::new(preset, &mut rng))
        } else {
            Box::new(XlaLmEngine::new(preset, &rt, &mut rng).unwrap())
        };
        LmTrainer::new(opts, eng, Some(&rt)).unwrap()
    };
    let mut tr_rust = mk("rust");
    let mut tr_xla = mk("xla");
    let ra = tr_rust.train_epoch(train, 16).unwrap();
    let rb = tr_xla.train_epoch(train, 16).unwrap();
    assert!(
        (ra.mean_loss - rb.mean_loss).abs() < 0.05 * ra.mean_loss,
        "rust {} vs xla {}",
        ra.mean_loss,
        rb.mean_loss
    );
}
