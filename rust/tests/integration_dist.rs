//! Cross-process sharded training equivalence suite (DESIGN.md §9) —
//! the PR 2 sharding-equivalence guarantees extended across process
//! boundaries.
//!
//! * In-process legs drive real multi-rank worlds over the in-memory
//!   transport (threads), comparing full training trajectories bitwise
//!   against the single-process path.
//! * The subprocess leg runs the actual `csopt launch` CLI (rank 0 +
//!   forked workers over unix sockets) and proves the acceptance
//!   criterion: a 2-worker launch is bit-identical (final params + valid
//!   ppl) to the same config run single-process with `shard=2`.
//! * Checkpoint legs prove shard- and worker-count independence of
//!   save/resume: a checkpoint written under one layout resumes under
//!   any other with bit-identical subsequent steps.

use std::thread;

use csopt::comm::{mem_world, DistCtx};
use csopt::data::corpus::SyntheticCorpus;
use csopt::train::checkpoint::Checkpoint;
use csopt::train::session::{RunSpec, Session};

fn lm_spec(extra: &str) -> RunSpec {
    let text = format!(
        "preset = tiny\nepochs = 1\nsteps = 8\neval.windows = 2\n{extra}\n\
         [optim]\nemb = \"cs-adam@v=2,w=48,clean=0.5/4\"\nsm = \"cs-adagrad@w=32\"\n"
    );
    RunSpec::parse(&text).unwrap()
}

/// Full LmTrainer trajectories over 1/2/3 mem-transport ranks must be
/// bit-identical to the single-process trainer — every rank, not just
/// rank 0, because replicated compute is what keeps the partition sound.
#[test]
fn distributed_trainer_matches_single_process_bitwise() {
    let spec = lm_spec("");
    let corpus = SyntheticCorpus::generate(512, 16_000, 1.05, 0.6, 9);
    let (train, _, _) = corpus.split(0.1, 0.05);

    let mut seq = Session::build_trainer(&spec).unwrap();
    let r_seq = seq.train_epoch(train, 8).unwrap();
    let seq_sketch_bytes = seq.emb.opt.memory_bytes() + seq.sm.opt.memory_bytes();

    for world in [1usize, 2, 3] {
        let outs: Vec<(f64, Vec<f32>, Vec<f32>, usize)> = thread::scope(|s| {
            let handles: Vec<_> = mem_world(world)
                .into_iter()
                .enumerate()
                .map(|(rank, ep)| {
                    let spec = spec.clone();
                    s.spawn(move || {
                        let ctx = DistCtx::new(rank, world, ep);
                        let mut tr =
                            Session::build_trainer_dist(&spec, Some(&ctx)).unwrap();
                        let r = tr.train_epoch(train, 8).unwrap();
                        let sketch_bytes =
                            tr.emb.opt.memory_bytes() + tr.sm.opt.memory_bytes();
                        (r.mean_loss, tr.emb.params.clone(), tr.sm.params.clone(), sketch_bytes)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut total_sketch_bytes = 0usize;
        for (rank, (loss, emb, sm, sketch_bytes)) in outs.iter().enumerate() {
            assert_eq!(
                loss.to_bits(),
                r_seq.mean_loss.to_bits(),
                "mean loss diverged (world={world} rank={rank})"
            );
            assert_eq!(emb, &seq.emb.params, "emb params diverged (world={world} rank={rank})");
            assert_eq!(sm, &seq.sm.params, "sm params diverged (world={world} rank={rank})");
            total_sketch_bytes += sketch_bytes;
        }
        // the width partition tiles the sketch exactly once: per-rank
        // shares sum to the single-process footprint (the paper's memory
        // claim, now divided by N processes)
        assert_eq!(total_sketch_bytes, seq_sketch_bytes, "world={world}");
    }
}

/// A checkpoint written under `shard=4` resumed with `shards = 1` (and
/// with `shards = 4`) must produce bit-identical subsequent steps —
/// shard count is execution layout, not trained state.
#[test]
fn checkpoint_resumes_across_shard_counts() {
    let dir = std::env::temp_dir().join(format!("csopt_dist_shard_ck_{}", std::process::id()));
    let ck = dir.join("sharded.ck").display().to_string();

    let mut spec = lm_spec("shards = 4\n");
    spec.checkpoint = Some(ck.clone());
    Session::build(&spec).unwrap().run().unwrap();

    let mut resumed: Vec<(f64, Vec<f32>)> = Vec::new();
    for shards in [1usize, 4] {
        let mut rspec = lm_spec(&format!("shards = {shards}\n"));
        rspec.resume = Some(ck.clone());
        let mut session = Session::build(&rspec).unwrap();
        let r = session.epoch().unwrap();
        resumed.push((r.mean_loss, session.trainer.emb.params.clone()));
    }
    assert_eq!(resumed[0].0.to_bits(), resumed[1].0.to_bits(), "post-resume loss diverged");
    assert_eq!(resumed[0].1, resumed[1].1, "post-resume emb params diverged");

    let _ = std::fs::remove_dir_all(dir);
}

/// Same independence across *worker* counts, in-process: a checkpoint
/// from a 2-rank mem-transport run resumed single-process continues
/// bit-identically to the single-process checkpoint's continuation.
#[test]
fn checkpoint_resumes_across_worker_counts() {
    let dir = std::env::temp_dir().join(format!("csopt_dist_worker_ck_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ck_single = dir.join("single.ck").display().to_string();
    let ck_dist = dir.join("dist.ck").display().to_string();
    let spec = lm_spec("");
    let corpus = SyntheticCorpus::generate(512, 16_000, 1.05, 0.6, 9);
    let (train, _, _) = corpus.split(0.1, 0.05);

    // single-process reference checkpoint (params + step only — aux
    // optimizer state intentionally restarts on resume, which is what
    // makes layout-independent resumes exact)
    {
        let mut tr = Session::build_trainer(&spec).unwrap();
        tr.train_epoch(train, 8).unwrap();
        let mut s = Session::build(&spec).unwrap();
        s.trainer = tr;
        s.save_checkpoint(&ck_single).unwrap();
    }
    // 2-rank world writes rank 0's view of the same run
    let world = 2usize;
    thread::scope(|scope| {
        let handles: Vec<_> = mem_world(world)
            .into_iter()
            .enumerate()
            .map(|(rank, ep)| {
                let spec = spec.clone();
                let ck_dist = ck_dist.clone();
                scope.spawn(move || {
                    let ctx = DistCtx::new(rank, world, ep);
                    let mut tr = Session::build_trainer_dist(&spec, Some(&ctx)).unwrap();
                    tr.train_epoch(train, 8).unwrap();
                    if rank == 0 {
                        let mut s = Session::build(&spec).unwrap();
                        s.trainer = tr;
                        s.save_checkpoint(&ck_dist).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });

    let a = Checkpoint::load(&ck_single).unwrap();
    let b = Checkpoint::load(&ck_dist).unwrap();
    assert_eq!(a.blobs, b.blobs, "2-rank checkpoint differs from single-process");
    assert_eq!(a.scalar("step").unwrap(), b.scalar("step").unwrap());

    // resume the 2-rank checkpoint single-process and the single-process
    // checkpoint single-process: continuations must match bitwise
    let mut conts: Vec<(f64, Vec<f32>)> = Vec::new();
    for ck in [&ck_dist, &ck_single] {
        let mut rspec = spec.clone();
        rspec.resume = Some(ck.clone());
        let mut session = Session::build(&rspec).unwrap();
        let r = session.epoch().unwrap();
        conts.push((r.mean_loss, session.trainer.emb.params.clone()));
    }
    assert_eq!(conts[0].0.to_bits(), conts[1].0.to_bits());
    assert_eq!(conts[0].1, conts[1].1);

    let _ = std::fs::remove_dir_all(dir);
}

/// Pull the `valid ppl <x>` / `final test ppl: <x>` readings out of a
/// run's stdout (timing fields vary run to run, the ppl numbers must
/// not).
fn ppl_readings(stdout: &str) -> Vec<String> {
    let mut out = Vec::new();
    for line in stdout.lines() {
        if let Some(ix) = line.find("valid ppl ") {
            let rest = &line[ix + "valid ppl ".len()..];
            out.push(rest.split(',').next().unwrap().trim().to_string());
        }
        if let Some(rest) = line.strip_prefix("final test ppl: ") {
            out.push(rest.trim().to_string());
        }
    }
    out
}

fn run_csopt(args: &[&str]) -> (String, String) {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_csopt"))
        .args(args)
        .output()
        .expect("running csopt");
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    let stderr = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(out.status.success(), "csopt {args:?} failed:\n{stdout}\n{stderr}");
    (stdout, stderr)
}

/// The acceptance criterion, end to end through the real CLI: a 2-worker
/// `csopt launch` run (rank 0 + one forked worker over a unix socket) is
/// bit-identical — final params and valid/test perplexities — to the
/// same config run single-process with `shard=2`; and its checkpoint
/// resumes single-process with bit-identical subsequent steps.
#[cfg(unix)]
#[test]
fn launch_cli_matches_single_process_shard2() {
    let dir = std::env::temp_dir().join(format!("csopt_dist_launch_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("run.conf");
    std::fs::write(
        &cfg,
        "preset = tiny\nepochs = 1\nsteps = 6\neval.windows = 2\n\n\
         [optim]\nemb = \"cs-adam@v=2,w=48,clean=0.5/4\"\nsm = \"cs-adagrad@w=32\"\n",
    )
    .unwrap();
    let cfg = cfg.display().to_string();
    let ck_single = dir.join("single.ck").display().to_string();
    let ck_launch = dir.join("launch.ck").display().to_string();
    let socket = dir.join("launch.sock").display().to_string();

    let (out_single, _) =
        run_csopt(&["run", &cfg, "--set", &format!("shards=2,checkpoint={ck_single}")]);
    let (out_launch, _) = run_csopt(&[
        "launch",
        &cfg,
        "--workers",
        "2",
        "--socket",
        &socket,
        "--set",
        &format!("checkpoint={ck_launch}"),
    ]);

    // identical perplexity trajectory ...
    let ppl_single = ppl_readings(&out_single);
    let ppl_launch = ppl_readings(&out_launch);
    assert!(!ppl_single.is_empty(), "no ppl readings in:\n{out_single}");
    assert_eq!(ppl_single, ppl_launch, "\n--- run ---\n{out_single}\n--- launch ---\n{out_launch}");

    // ... and bit-identical final parameters
    let a = Checkpoint::load(&ck_single).unwrap();
    let b = Checkpoint::load(&ck_launch).unwrap();
    assert_eq!(a.scalar("step").unwrap(), b.scalar("step").unwrap());
    assert_eq!(a.blobs.keys().collect::<Vec<_>>(), b.blobs.keys().collect::<Vec<_>>());
    for (name, blob) in &a.blobs {
        assert_eq!(blob, &b.blobs[name], "checkpoint blob {name} differs");
    }

    // satellite: the 2-worker checkpoint resumed single-process continues
    // exactly like the single-process checkpoint does
    let ck_cont_a = dir.join("cont_a.ck").display().to_string();
    let ck_cont_b = dir.join("cont_b.ck").display().to_string();
    let (cont_a, _) = run_csopt(&[
        "run",
        &cfg,
        "--set",
        &format!("resume={ck_launch},checkpoint={ck_cont_a}"),
    ]);
    let (cont_b, _) = run_csopt(&[
        "run",
        &cfg,
        "--set",
        &format!("resume={ck_single},checkpoint={ck_cont_b},shards=2"),
    ]);
    assert_eq!(ppl_readings(&cont_a), ppl_readings(&cont_b));
    let ca = Checkpoint::load(&ck_cont_a).unwrap();
    let cb = Checkpoint::load(&ck_cont_b).unwrap();
    assert_eq!(ca.blobs, cb.blobs, "post-resume checkpoints differ");

    let _ = std::fs::remove_dir_all(dir);
}
