//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These require `make artifacts` and a real PJRT backend; they validate
//! the whole python-AOT → HLO-text → rust-load → execute chain
//! numerically, and skip cleanly when that chain is not available.

use csopt::runtime::Arg;

mod common;
use common::runtime_or_skip as runtime;

#[test]
fn smoke_axpy_runs_and_matches() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("smoke.axpy").unwrap();
    let outs = exe
        .call(&[Arg::ScalarF32(3.0), Arg::F32(&[1.0, 2.0, 3.0, 4.0])])
        .unwrap();
    let got: Vec<f32> = outs[0].to_vec().unwrap();
    assert_eq!(got, vec![5.0, 8.0, 11.0, 14.0]); // 3x + 2
}

#[test]
fn artifact_cache_returns_same_executable() {
    let Some(rt) = runtime() else { return };
    let a = rt.load("smoke.axpy").unwrap();
    let b = rt.load("smoke.axpy").unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
}

#[test]
fn call_validates_shapes() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("smoke.axpy").unwrap();
    // wrong arity
    assert!(exe.call(&[Arg::ScalarF32(1.0)]).is_err());
    // wrong shape
    assert!(exe.call(&[Arg::ScalarF32(1.0), Arg::F32(&[1.0, 2.0])]).is_err());
    // wrong dtype
    assert!(exe.call(&[Arg::ScalarI32(1), Arg::F32(&[1.0; 4])]).is_err());
}

#[test]
fn manifest_covers_tiny_preset() {
    let Some(rt) = runtime() else { return };
    assert!(rt.manifest.artifacts.contains_key("tiny.lm_step"));
    assert!(rt.manifest.artifacts.contains_key("tiny.lm_eval"));
    assert!(rt.manifest.hyper("hash_seed").unwrap() as u64 == 0x5EED);
    let p = &rt.manifest.presets["tiny"];
    assert_eq!(p["vocab"] as usize, 512);
}

/// The AOT dense-Adam row graph must match the Rust DenseAdam exactly.
#[test]
fn xla_dense_adam_matches_rust() {
    use csopt::optim::{DenseAdam, RowOptimizer};
    let Some(rt) = runtime() else { return };
    // tiny preset k=64, d=32
    let exe = rt.load("opt.dense_adam.k64.d32").unwrap();
    let (k, d) = (64usize, 32usize);
    let mut rust_opt = DenseAdam::new(k, d, 0.9, 0.999, 1e-8);
    let mut rng = csopt::util::rng::Rng::new(3);

    let mut rows_rust: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 0.5)).collect();
    let mut rows_xla = rows_rust.clone();
    let mut m = vec![0.0f32; k * d];
    let mut v = vec![0.0f32; k * d];
    let mask = vec![1.0f32; k];
    let ids: Vec<u64> = (0..k as u64).collect();

    for t in 1..=3 {
        let g: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        rust_opt.step_rows(&ids, &mut rows_rust, &g, 1e-3, t);
        let outs = exe
            .call(&[
                Arg::F32(&rows_xla),
                Arg::F32(&m),
                Arg::F32(&v),
                Arg::F32(&g),
                Arg::F32(&mask),
                Arg::ScalarF32(1e-3),
                Arg::ScalarF32(t as f32),
            ])
            .unwrap();
        outs[0].copy_raw_to(&mut rows_xla).unwrap();
        outs[1].copy_raw_to(&mut m).unwrap();
        outs[2].copy_raw_to(&mut v).unwrap();
    }
    for i in 0..k * d {
        assert!(
            (rows_rust[i] - rows_xla[i]).abs() < 1e-5,
            "row mismatch at {i}: {} vs {}",
            rows_rust[i],
            rows_xla[i]
        );
    }
}

/// The AOT **Pallas** CS-Adam graph must match the Rust CsAdam (identical
/// hashing, identical batched semantics) — this is the cross-language
/// correctness anchor for the whole L1 kernel stack.
#[test]
fn xla_pallas_cs_adam_matches_rust_cs_adam() {
    use csopt::optim::{CsAdam, RowOptimizer};
    use csopt::train::xla_opt::{XlaOptKind, XlaRowOptimizer};
    let Some(rt) = runtime() else { return };
    let seed = rt.manifest.hyper("hash_seed").unwrap() as u64;
    // tiny preset emb shapes: k=64, d=32, v=3, w=103
    let (k, d, v, w) = (64usize, 32usize, 3usize, 103usize);
    let mut xla_opt = XlaRowOptimizer::new(&rt, XlaOptKind::CsAdam, k, d, v, w, seed).unwrap();
    let mut rust_opt = CsAdam::new(v, w, d, seed, 0.9, 0.999, 1e-8);

    let mut rng = csopt::util::rng::Rng::new(5);
    // partial batch (tests masking too): 37 of 64 slots live
    let live = 37usize;
    let ids: Vec<u64> = rng.sample_distinct(512, live).into_iter().map(|x| x as u64).collect();
    let mut rows_a: Vec<f32> = (0..live * d).map(|_| rng.normal_f32(0.0, 0.5)).collect();
    let mut rows_b = rows_a.clone();
    for t in 1..=4 {
        let g: Vec<f32> = (0..live * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        rust_opt.step_rows(&ids, &mut rows_a, &g, 1e-2, t);
        xla_opt.step_rows(&ids, &mut rows_b, &g, 1e-2, t);
        for i in 0..live * d {
            assert!(
                (rows_a[i] - rows_b[i]).abs() < 1e-4 * (1.0 + rows_a[i].abs()),
                "t={t} i={i}: rust {} vs xla {}",
                rows_a[i],
                rows_b[i]
            );
        }
    }
}

/// Same anchor for CMS-Adagrad.
#[test]
fn xla_pallas_cms_adagrad_matches_rust() {
    use csopt::optim::{CmsAdagrad, RowOptimizer};
    use csopt::train::xla_opt::{XlaOptKind, XlaRowOptimizer};
    let Some(rt) = runtime() else { return };
    let seed = rt.manifest.hyper("hash_seed").unwrap() as u64;
    let (k, d, v, w) = (64usize, 32usize, 3usize, 103usize);
    let mut xla_opt = XlaRowOptimizer::new(&rt, XlaOptKind::CmsAdagrad, k, d, v, w, seed).unwrap();
    let mut rust_opt = CmsAdagrad::new(v, w, d, seed, 1e-10);
    let mut rng = csopt::util::rng::Rng::new(6);
    let ids: Vec<u64> = rng.sample_distinct(512, 20).into_iter().map(|x| x as u64).collect();
    let mut rows_a: Vec<f32> = (0..20 * d).map(|_| rng.normal_f32(0.0, 0.5)).collect();
    let mut rows_b = rows_a.clone();
    for t in 1..=3 {
        let g: Vec<f32> = (0..20 * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        rust_opt.step_rows(&ids, &mut rows_a, &g, 0.1, t);
        xla_opt.step_rows(&ids, &mut rows_b, &g, 0.1, t);
    }
    for i in 0..20 * d {
        assert!((rows_a[i] - rows_b[i]).abs() < 1e-4, "i={i}");
    }
}
