//! End-to-end suite for `sketchd` (`csopt serve`, DESIGN.md §13).
//!
//! Proves the three acceptance criteria through the real CLI:
//!
//! * **recover-not-err**: a worker killed mid-run (the deterministic
//!   `CSOPT_SERVE_ABORT_EPOCH` chaos hook — same code path a SIGKILL
//!   takes, without the race) stalls the world, the supervisor restarts
//!   the generation from the epoch snapshot, and the final checkpoint is
//!   **bitwise identical** to an uninterrupted same-seed serve run.
//! * **layout-independent rejoin**: a snapshot written by a 2-worker
//!   world restores into a 1-worker world (each member re-derives its
//!   own `width_partition` slice from the full-width blobs) and the
//!   continued run matches the never-partitioned reference bitwise.
//! * **non-perturbing reads**: hammering the query socket while training
//!   runs leaves the final checkpoint bitwise unchanged.
//!
//! Every test body runs under the `with_deadline` watchdog: a serve loop
//! that regresses to hanging fails in minutes, not a wedged CI job.
#![cfg(unix)]

mod common;

use std::time::Duration;

use csopt::serve::query;
use csopt::train::checkpoint::Checkpoint;

use common::with_deadline;

const DEADLINE: Duration = Duration::from_secs(240);

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("csopt_serve_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn write_config(dir: &std::path::Path, epochs: usize) -> String {
    let cfg = dir.join("serve.conf");
    std::fs::write(
        &cfg,
        format!(
            "preset = tiny\nepochs = {epochs}\nsteps = 6\neval.windows = 2\n\n\
             [optim]\nemb = \"cs-adam@v=2,w=48,clean=0.5/4\"\nsm = \"cs-adagrad@w=32\"\n"
        ),
    )
    .unwrap();
    cfg.display().to_string()
}

/// Run `csopt serve` to completion with optional chaos env, asserting
/// success; returns (stdout, stderr).
fn run_serve(args: &[&str], env: &[(&str, &str)]) -> (String, String) {
    let mut cmd = std::process::Command::new(env!("CARGO_BIN_EXE_csopt"));
    cmd.arg("serve").args(args);
    for (k, v) in env {
        cmd.env(k, v);
    }
    let out = cmd.output().expect("running csopt serve");
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    let stderr = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(out.status.success(), "csopt serve {args:?} failed:\n{stdout}\n{stderr}");
    (stdout, stderr)
}

fn assert_checkpoints_bitwise_equal(a: &str, b: &str) {
    let a = Checkpoint::load(a).unwrap();
    let b = Checkpoint::load(b).unwrap();
    assert_eq!(a.scalar("step").unwrap(), b.scalar("step").unwrap(), "step count differs");
    assert_eq!(
        a.blobs.keys().collect::<Vec<_>>(),
        b.blobs.keys().collect::<Vec<_>>(),
        "checkpoint blob inventories differ"
    );
    for (name, blob) in &a.blobs {
        assert_eq!(blob, &b.blobs[name], "checkpoint blob {name} differs");
    }
}

/// The tentpole acceptance: kill worker rank 1 after epoch 2 (before the
/// snapshot — the worst loss point), and the run still completes with a
/// final checkpoint bitwise identical to the uninterrupted run's.
#[test]
fn killed_worker_rejoins_and_final_state_is_bitwise_identical() {
    with_deadline(DEADLINE, || {
        let dir = tmp_dir("rejoin");
        let cfg = write_config(&dir, 3);
        let ck_base = dir.join("base.ck").display().to_string();
        let ck_chaos = dir.join("chaos.ck").display().to_string();

        // uninterrupted 2-worker reference
        run_serve(
            &[
                &cfg,
                "--workers",
                "2",
                "--socket",
                &dir.join("base.sock").display().to_string(),
                "--snapshot",
                &dir.join("base.snap").display().to_string(),
                "--set",
                &format!("checkpoint={ck_base}"),
            ],
            &[],
        );

        // same run, rank 1 dies after epoch 2 → generation restart
        let (_, stderr) = run_serve(
            &[
                &cfg,
                "--workers",
                "2",
                "--socket",
                &dir.join("chaos.sock").display().to_string(),
                "--snapshot",
                &dir.join("chaos.snap").display().to_string(),
                "--heartbeat-ms",
                "15000",
                "--set",
                &format!("checkpoint={ck_chaos}"),
            ],
            &[("CSOPT_SERVE_ABORT_EPOCH", "2"), ("CSOPT_SERVE_ABORT_RANK", "1")],
        );
        assert!(
            stderr.contains("restarting world (generation 2)"),
            "no generation restart in:\n{stderr}"
        );
        assert!(
            stderr.contains("run completed after 2 generations"),
            "run did not recover in:\n{stderr}"
        );

        assert_checkpoints_bitwise_equal(&ck_base, &ck_chaos);
        let _ = std::fs::remove_dir_all(dir);
    });
}

/// Layout-independent rejoin: epochs 1–2 trained by a 2-worker world,
/// epochs 3–4 by a 1-worker world restoring the same snapshot — final
/// state bitwise equal to a pure single-process 4-epoch serve.
#[test]
fn snapshot_rejoins_under_a_different_world_size() {
    with_deadline(DEADLINE, || {
        let dir = tmp_dir("reworld");
        let cfg = write_config(&dir, 4);
        let ck_ref = dir.join("ref.ck").display().to_string();
        let ck_mixed = dir.join("mixed.ck").display().to_string();
        let snap_mixed = dir.join("mixed.snap").display().to_string();

        // reference: single-process all the way
        run_serve(
            &[
                &cfg,
                "--snapshot",
                &dir.join("ref.snap").display().to_string(),
                "--set",
                &format!("checkpoint={ck_ref}"),
            ],
            &[],
        );

        // epochs 1–2 under 2 workers (stop by lowering epochs)…
        run_serve(
            &[
                &cfg,
                "--workers",
                "2",
                "--socket",
                &dir.join("mixed.sock").display().to_string(),
                "--snapshot",
                &snap_mixed,
                "--set",
                "epochs=2",
            ],
            &[],
        );
        // …then epochs 3–4 single-process from the 2-worker snapshot
        let (stdout, _) = run_serve(
            &[&cfg, "--snapshot", &snap_mixed, "--set", &format!("checkpoint={ck_mixed}")],
            &[],
        );
        assert!(
            stdout.contains("restored snapshot") && stdout.contains("epochs done 2"),
            "single-process leg did not restore the 2-worker snapshot:\n{stdout}"
        );

        assert_checkpoints_bitwise_equal(&ck_ref, &ck_mixed);
        let _ = std::fs::remove_dir_all(dir);
    });
}

/// Concurrent reads are non-perturbing: hammer the query socket for the
/// whole run (ping + stats + parameter rows + sketch materialization);
/// the final checkpoint must be bitwise identical to a run with no
/// query socket at all — and the queries themselves must succeed.
#[test]
fn query_traffic_leaves_training_bitwise_unchanged() {
    with_deadline(DEADLINE, || {
        let dir = tmp_dir("query");
        let cfg = write_config(&dir, 3);
        let ck_quiet = dir.join("quiet.ck").display().to_string();
        let ck_queried = dir.join("queried.ck").display().to_string();
        let qsock = dir.join("q.sock").display().to_string();

        // no read path at all
        run_serve(
            &[
                &cfg,
                "--snapshot",
                &dir.join("quiet.snap").display().to_string(),
                "--set",
                &format!("checkpoint={ck_quiet}"),
            ],
            &[],
        );

        // same run with the query server up and a client hammering it
        let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_csopt"))
            .args([
                "serve",
                &cfg,
                "--snapshot",
                &dir.join("queried.snap").display().to_string(),
                "--query-socket",
                &qsock,
                "--set",
                &format!("checkpoint={ck_queried}"),
            ])
            .stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::piped())
            .spawn()
            .expect("spawning csopt serve");

        let mut reads_ok = 0usize;
        let mut row_dim = 0usize;
        let mut sketch_ok = false;
        loop {
            if let Some(status) = child.try_wait().expect("polling csopt serve") {
                assert!(status.success(), "queried serve run failed");
                break;
            }
            // the socket only exists once the lead rank is up, and
            // answers only after the first epoch's snapshot — failures
            // here are expected early, so just keep hammering
            if let Ok((epoch, step)) = query::client_ping(&qsock) {
                assert!(epoch >= 1 && step >= 1);
                if let Ok((name, d, rows)) = query::client_rows(&qsock, "query", "emb", &[0, 3])
                {
                    assert_eq!(name, "emb");
                    assert_eq!(rows.len(), 2 * d);
                    row_dim = d;
                    reads_ok += 1;
                }
                if let Ok((name, d, est)) =
                    query::client_rows(&qsock, "materialize", "emb.m", &[0, 3])
                {
                    assert_eq!(name, "emb.m");
                    assert_eq!(est.len(), 2 * d);
                    sketch_ok = true;
                }
                let _ = query::client_stats(&qsock);
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(reads_ok > 0, "no successful row read landed during the run");
        assert!(sketch_ok, "no successful sketch materialization landed during the run");
        assert!(row_dim > 0);

        assert_checkpoints_bitwise_equal(&ck_quiet, &ck_queried);
        let _ = std::fs::remove_dir_all(dir);
    });
}
