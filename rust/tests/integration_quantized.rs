//! Quantized-cell store suite (DESIGN.md §15).
//!
//! The claims pinned here, in order of strength:
//!
//! * **Exactness** — `cells=f32` is a pure refactor: bitwise-identical
//!   to [`LocalStore`] at the store level (update/query/scale/fold,
//!   fused and unfused, shards 1/2/4, both reductions), at the trainer
//!   level, and through a checkpoint round-trip.
//! * **Streaming clean** — the lazily-applied per-row clean is
//!   bitwise-identical to eagerly sweeping the full width at every
//!   `scale`, for lossy formats too.
//! * **Monotone underestimate** — `cells=i8` (floor-coded E5M3) never
//!   reports a CMS estimate above the f32 store's, under interleaved
//!   updates and cleans.
//! * **Tolerance** — `cells=bf16` genuinely quantizes (trajectories
//!   diverge) yet still trains: eval ppl within 1.05× of the f32 run,
//!   via the shared tolerance harness.
//! * **Memory** — bf16/i8 stores report roughly half / under half the
//!   f32 store's bytes, which is the point of the feature.

mod common;

use csopt::data::corpus::SyntheticCorpus;
use csopt::sketch::store::LocalBuilder;
use csopt::sketch::{
    CellFormat, QuantizedBuilder, QuantizedStore, Reduce, SketchHasher, SketchPlan, SketchStore,
    StoreBuilder,
};
use csopt::train::checkpoint::Checkpoint;
use csopt::train::session::{RunSpec, Session};
use csopt::util::proptest::check;
use csopt::util::rng::Rng;

use common::tolerance;

// ---------------------------------------------------------------------------
// store-level exactness: cells=f32 vs LocalStore

/// Distinct random ids and matching `[k, d]` deltas; `signed = false`
/// callers get non-negative deltas (count-min convention).
fn random_batch(
    rng: &mut Rng,
    id_space: u64,
    k_max: usize,
    d: usize,
    signed: bool,
) -> (Vec<u64>, Vec<f32>) {
    let mut ids: Vec<u64> =
        (0..1 + rng.below(k_max)).map(|_| rng.next_u64() % id_space).collect();
    ids.sort_unstable();
    ids.dedup();
    let deltas: Vec<f32> = (0..ids.len() * d)
        .map(|_| {
            let x = rng.normal_f32(0.0, 1.0);
            if signed {
                x
            } else {
                x.abs()
            }
        })
        .collect();
    (ids, deltas)
}

/// Unfused interleaving of update / query / scale / sq_norm across both
/// reductions and shard counts 1/2/4: every observable of the f32-cell
/// quantized store must match the reference store bit for bit.
#[test]
fn f32_cells_match_local_store_bitwise_unfused() {
    check("quant-f32-unfused-bitwise", 10, 0xF32_0001, |rng| {
        let v = 1 + rng.below(3);
        let w = 16 + rng.below(48);
        let d = 1 + rng.below(8);
        let signed = rng.below(2) == 0;
        let reduce = if signed { Reduce::SignedMedian } else { Reduce::Min };
        let shards = [1usize, 2, 4][rng.below(3)];
        let hasher = SketchHasher::new(v, w, rng.next_u64());

        let mut reference = LocalBuilder.build(v, w, d);
        let mut quant = QuantizedBuilder::new(CellFormat::F32).build(v, w, d);
        reference.set_shards(shards);
        quant.set_shards(shards);

        for round in 0..8 {
            let (ids, deltas) = random_batch(rng, 500, 24, d, signed);
            let plan = SketchPlan::build(&hasher, &ids);
            reference.update(&plan, &deltas, signed);
            quant.update(&plan, &deltas, signed);
            if round % 3 == 2 {
                reference.scale(0.5);
                quant.scale(0.5);
            }
            let mut out_a = vec![0.0f32; plan.k() * d];
            let mut out_b = vec![0.0f32; plan.k() * d];
            reference.query(&plan, reduce, &mut out_a);
            quant.query(&plan, reduce, &mut out_b);
            for (i, (&a, &b)) in out_a.iter().zip(&out_b).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!(
                        "round {round} query cell {i}: local {a} vs quant {b} \
                         (v={v} w={w} d={d} shards={shards} signed={signed})"
                    ));
                }
            }
            if reference.sq_norm().to_bits() != quant.sq_norm().to_bits() {
                return Err(format!("round {round}: sq_norm diverged"));
            }
        }
        if reference.snapshot_full() != quant.snapshot_full() {
            return Err("final snapshots differ".into());
        }
        reference.fold_half();
        quant.fold_half();
        if reference.snapshot_full() != quant.snapshot_full() {
            return Err("snapshots differ after fold_half".into());
        }
        Ok(())
    });
}

/// Fused steps: the reference store runs its gather-once fused kernel,
/// the quantized store the default unfused decomposition — the
/// `step_fused` contract says both are bitwise-identical, and f32 cells
/// must preserve that across shard counts.
#[test]
fn f32_cells_match_local_store_bitwise_fused() {
    for shards in [1usize, 2, 4] {
        let (v, w, d) = (3, 64, 8);
        let hasher = SketchHasher::new(v, w, 0xF0_5ED + shards as u64);
        let mut reference = LocalBuilder.build(v, w, d);
        let mut quant = QuantizedBuilder::new(CellFormat::F32).build(v, w, d);
        reference.set_shards(shards);
        quant.set_shards(shards);

        let mut rng = Rng::new(99 + shards as u64);
        for round in 0..6 {
            let (ids, grads) = random_batch(&mut rng, 400, 20, d, true);
            let plan = SketchPlan::build(&hasher, &ids);
            let mut est_a = vec![0.0f32; plan.k() * d];
            let mut est_b = vec![0.0f32; plan.k() * d];
            // an Adam-shaped delta: decay the estimate toward the gradient
            let mut make = |est: &[f32], delta: &mut [f32]| {
                for (i, dst) in delta.iter_mut().enumerate() {
                    *dst = 0.1 * (grads[i] - est[i]);
                }
            };
            reference.step_fused(&plan, Reduce::SignedMedian, true, true, &mut make, &mut est_a);
            quant.step_fused(&plan, Reduce::SignedMedian, true, true, &mut make, &mut est_b);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(
                bits(&est_a),
                bits(&est_b),
                "shards={shards} round={round}: fused re-query diverged"
            );
            if round == 3 {
                reference.scale(0.25);
                quant.scale(0.25);
            }
        }
        assert_eq!(
            reference.snapshot_full(),
            quant.snapshot_full(),
            "shards={shards}: fused trajectories left different state"
        );
    }
}

// ---------------------------------------------------------------------------
// streaming clean

/// Lazy per-row clean catch-up vs eagerly flushing the full width at
/// every scale: bitwise-identical final cells, for a lossy format, with
/// enough interleaved scales to cross the pending-clean flush cap.
#[test]
fn streaming_clean_matches_full_width_clean_bitwise() {
    check("quant-streaming-clean", 8, 0xC1EA_17, |rng| {
        let (v, w, d) = (2, 32 + rng.below(32), 1 + rng.below(6));
        let hasher = SketchHasher::new(v, w, rng.next_u64());
        let mut lazy = QuantizedStore::zeros(CellFormat::Bf16, v, w, d);
        let mut eager = QuantizedStore::zeros(CellFormat::Bf16, v, w, d);

        for _ in 0..40 {
            // scale more often than update so pending cleans accumulate
            // past MAX_PENDING_CLEANS on some rows
            let (ids, deltas) = random_batch(rng, 300, 12, d, true);
            let plan = SketchPlan::build(&hasher, &ids);
            lazy.update(&plan, &deltas, true);
            eager.update(&plan, &deltas, true);
            for _ in 0..1 + rng.below(3) {
                lazy.scale(0.9);
                eager.scale(0.9);
                eager.flush_clean();
            }
        }
        let (a, b) = (lazy.snapshot_full(), eager.snapshot_full());
        for (i, (&x, &y)) in a.iter().zip(&b).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Err(format!("cell {i}: lazy {x} vs eager {y}"));
            }
        }
        lazy.flush_clean();
        if lazy.pending_cleans() != 0 {
            return Err("flush_clean left pending cleans".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// i8 monotone underestimate

/// Floor-coded i8 cells under count-min semantics: with non-negative
/// deltas and interleaved cleans, the quantized estimate never exceeds
/// the exact f32 estimate — the property `validate()` relies on when it
/// admits `cells=i8` for cs-adagrad only.
#[test]
fn i8_cms_estimate_never_exceeds_f32() {
    check("quant-i8-monotone", 12, 0x18_F10_0C, |rng| {
        let (v, w, d) = (1 + rng.below(3), 16 + rng.below(48), 1 + rng.below(4));
        let hasher = SketchHasher::new(v, w, rng.next_u64());
        let mut exact = LocalBuilder.build(v, w, d);
        let mut quant = QuantizedBuilder::new(CellFormat::I8).build(v, w, d);

        for round in 0..10 {
            let (ids, deltas) = random_batch(rng, 200, 16, d, false);
            let plan = SketchPlan::build(&hasher, &ids);
            exact.update(&plan, &deltas, false);
            quant.update(&plan, &deltas, false);
            if round % 4 == 3 {
                exact.scale(0.5);
                quant.scale(0.5);
            }
            let mut est_f32 = vec![0.0f32; plan.k() * d];
            let mut est_i8 = vec![0.0f32; plan.k() * d];
            exact.query(&plan, Reduce::Min, &mut est_f32);
            quant.query(&plan, Reduce::Min, &mut est_i8);
            for (i, (&e, &q)) in est_f32.iter().zip(&est_i8).enumerate() {
                if q > e {
                    return Err(format!(
                        "round {round} cell {i}: i8 estimate {q} exceeds f32 {e} \
                         (v={v} w={w} d={d})"
                    ));
                }
                if q < 0.0 {
                    return Err(format!("round {round} cell {i}: negative CMS estimate {q}"));
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// trainer + checkpoint level

fn quant_spec(cells: &str) -> RunSpec {
    let cells = if cells.is_empty() { String::new() } else { format!(",cells={cells}") };
    let text = format!(
        "preset = tiny\nepochs = 1\nsteps = 8\neval.windows = 2\n\n\
         [optim]\nemb = \"cs-adam@v=2,w=48,clean=0.5/4{cells}\"\nsm = \"cs-adagrad@w=32{cells}\"\n"
    );
    RunSpec::parse(&text).unwrap()
}

/// `cells=f32` through the full trainer: identical parameters, eval
/// perplexity, and serve-style checkpoint blobs (which round-trip the
/// quantized store's `snapshot_full`/`restore_full` overrides).
#[test]
fn trainer_cells_f32_is_bitwise_identical_and_checkpoints_match() {
    let corpus = SyntheticCorpus::generate(512, 60_000, 1.05, 0.6, 31);
    let (train, valid, _) = corpus.split(0.08, 0.05);

    let mut reference = Session::build_trainer(&quant_spec("")).unwrap();
    let mut quant = Session::build_trainer(&quant_spec("f32")).unwrap();
    let ra = reference.train_epoch(train, 8).unwrap();
    let rb = quant.train_epoch(train, 8).unwrap();
    assert_eq!(
        ra.mean_loss.to_bits(),
        rb.mean_loss.to_bits(),
        "cells=f32: mean loss diverged from the unquantized store"
    );
    assert_eq!(reference.emb.params, quant.emb.params, "emb params diverged");
    assert_eq!(reference.sm.params, quant.sm.params, "sm params diverged");
    let pa = reference.eval_ppl(valid, 2).unwrap();
    let pb = quant.eval_ppl(valid, 2).unwrap();
    assert_eq!(pa.to_bits(), pb.to_bits(), "valid ppl diverged");

    // checkpoint level: identical blobs, and restoring the quantized
    // trainer from its own checkpoint continues bitwise-identically
    let (mut ck_a, mut ck_b) = (Checkpoint::new(), Checkpoint::new());
    reference.snapshot_state(&mut ck_a).unwrap();
    quant.snapshot_state(&mut ck_b).unwrap();
    assert_eq!(ck_a.blobs, ck_b.blobs, "checkpoint blobs diverged");

    let mut resumed = Session::build_trainer(&quant_spec("f32")).unwrap();
    resumed.restore_state(&ck_b).unwrap();
    let rc = resumed.train_epoch(train, 8).unwrap();
    let rq = quant.train_epoch(train, 8).unwrap();
    assert_eq!(
        rq.mean_loss.to_bits(),
        rc.mean_loss.to_bits(),
        "restored cells=f32 trainer diverged from the live one"
    );
    assert_eq!(quant.emb.params, resumed.emb.params, "post-restore emb params diverged");
}

/// `cells=bf16` genuinely quantizes — the parameter trajectory diverges
/// from f32 — but still trains to within 1.05× of the f32 run's eval
/// perplexity. On failure the trajectory report pinpoints where the runs
/// parted ways.
#[test]
fn trainer_cells_bf16_trains_within_tolerance_of_f32() {
    let corpus = SyntheticCorpus::generate(512, 120_000, 1.05, 0.6, 32);
    let (train, valid, _) = corpus.split(0.08, 0.05);

    let mut f32_run = Session::build_trainer(&quant_spec("f32")).unwrap();
    let mut bf16_run = Session::build_trainer(&quant_spec("bf16")).unwrap();

    // five 6-step segments, snapshotting the embedding between segments,
    // so a tolerance failure reports *when* the trajectories split
    let (mut traj_f32, mut traj_bf16) = (Vec::new(), Vec::new());
    for _ in 0..5 {
        f32_run.train_epoch(train, 6).unwrap();
        bf16_run.train_epoch(train, 6).unwrap();
        traj_f32.push(f32_run.emb.params.clone());
        traj_bf16.push(bf16_run.emb.params.clone());
    }
    let report = tolerance::compare_trajectories(&traj_f32, &traj_bf16);
    assert!(
        !report.bitwise_identical(),
        "cells=bf16 must not silently keep f32 cells"
    );

    let ppl_f32 = f32_run.eval_ppl(valid, 4).unwrap();
    let ppl_bf16 = bf16_run.eval_ppl(valid, 4).unwrap();
    tolerance::assert_ppl_within(
        &format!("cells=bf16 vs f32 ({})", report.describe()),
        ppl_bf16,
        ppl_f32,
        1.05,
    );
}

// ---------------------------------------------------------------------------
// memory

/// The reported footprint is the feature: bf16 ≈ half, i8 ≈ a quarter of
/// the f32 cells (plus small per-row bookkeeping).
#[test]
fn quantized_store_memory_shrinks_as_advertised() {
    let (v, w, d) = (3, 4096, 64);
    let f32_bytes = QuantizedBuilder::new(CellFormat::F32).build(v, w, d).memory_bytes();
    let bf16_bytes = QuantizedBuilder::new(CellFormat::Bf16).build(v, w, d).memory_bytes();
    let i8_bytes = QuantizedBuilder::new(CellFormat::I8).build(v, w, d).memory_bytes();
    let local_bytes = LocalBuilder.build(v, w, d).memory_bytes();

    assert!(
        (bf16_bytes as f64) < 0.65 * f32_bytes as f64,
        "bf16 {bf16_bytes} vs f32 {f32_bytes}: not ~half"
    );
    assert!(
        (i8_bytes as f64) < 0.45 * f32_bytes as f64,
        "i8 {i8_bytes} vs f32 {f32_bytes}: not ~quarter"
    );
    // cells dominate: the quantized f32 store's bookkeeping overhead over
    // the plain local store stays modest
    assert!(
        (f32_bytes as f64) < 1.25 * local_bytes as f64,
        "quantized-f32 {f32_bytes} vs local {local_bytes}: bookkeeping too heavy"
    );
}
