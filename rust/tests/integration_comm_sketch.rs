//! `mode = comm-sketch` equivalence + compression suite (DESIGN.md §11).
//!
//! The mode is **lossy** — the recovered top-k update is not the dense
//! averaged gradient — so its test story differs from §9/§10's:
//!
//! * Property legs pin the *wire protocol's* exactness: count-sketch
//!   linearity on integer grids, and single-owner replica slots
//!   surviving a real multi-rank all-reduce bit-for-bit.
//! * Trainer legs prove the determinism boundary: every multi-rank
//!   layout decodes the identical aggregate, so the full lossy
//!   trajectory is bitwise-equal to the `workers = 1` reference layout
//!   of the same replica count.
//! * A tolerance leg checks the compressed run still *trains*: its
//!   final eval perplexity stays within a stated factor of the dense
//!   `mode = data` run of the same config.
//! * The CLI legs run the real `csopt launch --mode comm-sketch` and
//!   read the metrics CSV's transport byte counters: the compressed
//!   exchange ships ≥ 4× fewer bytes per run than `mode = data`.

mod common;

use std::thread;

use csopt::comm::{mem_world, DistCtx, SegmentSketcher, Transport};
use csopt::data::corpus::SyntheticCorpus;
use csopt::train::checkpoint::Checkpoint;
use csopt::train::session::{RunSpec, Session};
use csopt::util::proptest::check;

// ---------------------------------------------------------------------------
// property legs: the wire protocol's exact substrate

/// Linearity across a *real* collective: each rank sketches its own
/// integer-valued gradient, the sketches all-reduce, and the aggregate
/// equals the sketch of the summed gradient bit-for-bit.
#[test]
fn sketch_all_reduce_equals_sketch_of_sum() {
    check("comm-sketch-reduce-linearity", 12, 0xC5_11, |rng| {
        let world = 2 + rng.below(2);
        let depth = 1 + rng.below(3);
        let width = 16 + rng.below(64);
        let n = 1 + rng.below(200);
        let seed = rng.next_u64();
        let ids: Vec<u64> = (0..n as u64).collect();
        let grads: Vec<Vec<f32>> = (0..world)
            .map(|_| (0..n).map(|_| (rng.below(2001) as f32) - 1000.0).collect())
            .collect();
        // what the ranks produce over the transport
        let outs: Vec<Vec<f32>> = thread::scope(|s| {
            let handles: Vec<_> = mem_world(world)
                .into_iter()
                .enumerate()
                .map(|(rank, mut ep)| {
                    let (ids, vals) = (ids.clone(), grads[rank].clone());
                    s.spawn(move || {
                        let mut sk = SegmentSketcher::new(depth, width, seed);
                        let mut wire = vec![0.0f32; sk.sketch_len()];
                        sk.encode(&ids, &vals, &mut wire);
                        ep.all_reduce_sum(&mut wire).unwrap();
                        wire
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // the sketch of the summed gradient (exact: integer-valued f32)
        let mut sum = vec![0.0f32; n];
        for g in &grads {
            for (s, &x) in sum.iter_mut().zip(g) {
                *s += x;
            }
        }
        let mut sk = SegmentSketcher::new(depth, width, seed);
        let mut expect = vec![0.0f32; sk.sketch_len()];
        sk.encode(&ids, &sum, &mut expect);
        for (rank, out) in outs.iter().enumerate() {
            for (i, (&a, &b)) in out.iter().zip(&expect).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!(
                        "rank {rank} cell {i}: reduced {a} != sketch-of-sum {b} \
                         (world={world} depth={depth} width={width} n={n})"
                    ));
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// trainer legs (in-memory transport, real multi-rank worlds)

fn cs_spec(extra_dist: &str) -> RunSpec {
    let text = format!(
        "preset = tiny\nepochs = 1\nsteps = 8\neval.windows = 2\n\n\
         [optim]\nemb = \"cs-adam@v=2,w=48,clean=0.5/4\"\nsm = \"cs-adagrad@w=32\"\n\n\
         [dist]\nmode = comm-sketch\n{extra_dist}"
    );
    RunSpec::parse(&text).unwrap()
}

/// One rank's full observable state after an epoch.
#[derive(PartialEq)]
struct Snapshot {
    loss_bits: u64,
    emb: Vec<f32>,
    sm: Vec<f32>,
    bias: Vec<f32>,
    flat: Vec<f32>,
    ppl_bits: u64,
}

fn run_rank(spec: &RunSpec, ctx: Option<&DistCtx>, train: &[u32], valid: &[u32]) -> Snapshot {
    let mut tr = Session::build_trainer_dist(spec, ctx).unwrap();
    assert!(tr.is_comm_sketch(), "spec did not wire the compressor in");
    let r = tr.train_epoch(train, 8).unwrap();
    let ppl = tr.eval_ppl(valid, 2).unwrap();
    let mut flat = Vec::new();
    tr.engine.pack_flat(&mut flat);
    Snapshot {
        loss_bits: r.mean_loss.to_bits(),
        emb: tr.emb.params.clone(),
        sm: tr.sm.params.clone(),
        bias: tr.sm_bias.params.clone(),
        flat,
        ppl_bits: ppl.to_bits(),
    }
}

fn assert_snapshots_match(a: &Snapshot, b: &Snapshot, what: &str) {
    assert_eq!(a.loss_bits, b.loss_bits, "{what}: mean loss diverged");
    assert_eq!(a.emb, b.emb, "{what}: emb params diverged");
    assert_eq!(a.sm, b.sm, "{what}: sm params diverged");
    assert_eq!(a.bias, b.bias, "{what}: bias params diverged");
    assert_eq!(a.flat, b.flat, "{what}: trunk params diverged");
    assert_eq!(a.ppl_bits, b.ppl_bits, "{what}: valid ppl diverged");
}

/// The determinism boundary: multi-rank comm-sketch trajectories over the
/// mem transport are bit-identical to the `workers = 1` reference layout
/// — every rank, for `replicas == workers`, `replicas > workers`
/// (multi-stripe-per-rank) and 3-rank worlds. Lossy ≠ nondeterministic.
#[test]
fn comm_sketch_trainer_matches_reference_layout_bitwise() {
    let corpus = SyntheticCorpus::generate(512, 60_000, 1.05, 0.6, 21);
    let (train, valid, _) = corpus.split(0.08, 0.05);

    for (workers, replicas) in [(2usize, 2usize), (2, 4), (3, 3)] {
        let reference = run_rank(
            &cs_spec(&format!("replicas = {replicas}\n")),
            None,
            train,
            valid,
        );
        let outs: Vec<Snapshot> = thread::scope(|s| {
            let handles: Vec<_> = mem_world(workers)
                .into_iter()
                .enumerate()
                .map(|(rank, ep)| {
                    let mut spec = cs_spec(&format!(
                        "rank = {rank}\nworkers = {workers}\nreplicas = {replicas}\n"
                    ));
                    spec.dist.as_mut().unwrap().rank = rank;
                    s.spawn(move || {
                        let ctx = DistCtx::new(rank, workers, ep);
                        run_rank(&spec, Some(&ctx), train, valid)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (rank, out) in outs.iter().enumerate() {
            assert_snapshots_match(
                out,
                &reference,
                &format!("comm-sketch workers={workers} replicas={replicas} rank={rank}"),
            );
        }
    }
}

/// The compressed exchange is genuinely lossy — its trajectory must
/// *differ* from dense `mode = data` (guards against the comm-sketch
/// mode silently falling through to the dense path) — while still
/// training: final valid/test perplexity within 1.5× of the dense run's.
#[test]
fn comm_sketch_trains_within_tolerance_of_dense_data_mode() {
    let corpus = SyntheticCorpus::generate(512, 120_000, 1.05, 0.6, 22);
    let (train, valid, _) = corpus.split(0.08, 0.05);

    let dense_spec =
        RunSpec::parse("preset = tiny\nepochs = 1\nsteps = 30\n\n[optim]\nemb = \"cs-adam\"\nsm = \"cs-adam\"\n\n[dist]\nmode = data\nreplicas = 2\n")
            .unwrap();
    let mut dense = Session::build_trainer_dist(&dense_spec, None).unwrap();
    dense.train_epoch(train, 30).unwrap();
    let dense_ppl = dense.eval_ppl(valid, 4).unwrap();

    // generous wire geometry: the tolerance leg tests "still trains",
    // the CLI leg below tests the byte savings
    let cs_spec =
        RunSpec::parse("preset = tiny\nepochs = 1\nsteps = 30\n\n[optim]\nemb = \"cs-adam\"\nsm = \"cs-adam\"\n\n[dist]\nmode = comm-sketch\nreplicas = 2\ncomm_w = 2048\ncomm_k = 1024\n")
            .unwrap();
    let mut cs = Session::build_trainer_dist(&cs_spec, None).unwrap();
    cs.train_epoch(train, 30).unwrap();
    let cs_ppl = cs.eval_ppl(valid, 4).unwrap();

    assert_ne!(
        dense.emb.params, cs.emb.params,
        "comm-sketch must not silently train the dense exchange"
    );
    common::tolerance::assert_ppl_within(
        "comm-sketch vs dense data mode",
        cs_ppl as f64,
        dense_ppl as f64,
        1.5,
    );
}

/// The mem transport's byte counters show the wire win without any
/// subprocess machinery: the same 2-rank epoch moves ≥ 4× fewer
/// gradient-exchange bytes under comm-sketch (default geometry) than
/// under dense `mode = data`.
#[test]
fn comm_sketch_moves_at_least_4x_fewer_bytes() {
    let corpus = SyntheticCorpus::generate(512, 60_000, 1.05, 0.6, 23);
    let (train, _, _) = corpus.split(0.08, 0.05);

    let bytes_for = |dist: &str| -> u64 {
        let spec = {
            let text = format!(
                "preset = tiny\nepochs = 1\nsteps = 4\n\n\
                 [optim]\nemb = \"cs-adam\"\nsm = \"cs-adam\"\n\n[dist]\n{dist}"
            );
            RunSpec::parse(&text).unwrap()
        };
        let workers = 2usize;
        let sents: Vec<u64> = thread::scope(|s| {
            let handles: Vec<_> = mem_world(workers)
                .into_iter()
                .enumerate()
                .map(|(rank, ep)| {
                    let mut spec = spec.clone();
                    spec.dist.as_mut().unwrap().rank = rank;
                    s.spawn(move || {
                        let ctx = DistCtx::new(rank, workers, ep);
                        let mut tr = Session::build_trainer_dist(&spec, Some(&ctx)).unwrap();
                        tr.train_epoch(train, 4).unwrap();
                        let t = ctx.comm();
                        let sent = t.lock().unwrap().bytes_sent();
                        drop(tr);
                        sent
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        sents[0]
    };

    // `sparse = false` pins the historical dense wire as the baseline —
    // the default owned-rows exchange already shrinks mode = data
    // (DESIGN.md §14), which would understate the compressor's 4×
    let dense = bytes_for("mode = data\nworkers = 2\nsparse = false\n");
    let compressed = bytes_for("mode = comm-sketch\nworkers = 2\n");
    assert!(dense > 0 && compressed > 0);
    assert!(
        dense >= 4 * compressed,
        "dense exchange {dense} bytes vs comm-sketch {compressed} bytes — less than 4×"
    );
}

// ---------------------------------------------------------------------------
// CLI legs (the real `csopt launch --mode comm-sketch` binary)

/// Pull the `valid ppl <x>` / `final test ppl: <x>` readings out of a
/// run's stdout.
#[cfg(unix)]
fn ppl_readings(stdout: &str) -> Vec<String> {
    let mut out = Vec::new();
    for line in stdout.lines() {
        if let Some(ix) = line.find("valid ppl ") {
            let rest = &line[ix + "valid ppl ".len()..];
            out.push(rest.split(',').next().unwrap().trim().to_string());
        }
        if let Some(rest) = line.strip_prefix("final test ppl: ") {
            out.push(rest.trim().to_string());
        }
    }
    out
}

#[cfg(unix)]
fn run_csopt(args: &[&str]) -> (String, String) {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_csopt"))
        .args(args)
        .output()
        .expect("running csopt");
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    let stderr = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(out.status.success(), "csopt {args:?} failed:\n{stdout}\n{stderr}");
    (stdout, stderr)
}

/// The cumulative `bytes_sent` of a metrics CSV's final row.
#[cfg(unix)]
fn final_bytes_sent(csv_path: &str) -> u64 {
    let text = std::fs::read_to_string(csv_path).unwrap();
    let mut lines = text.lines();
    let header: Vec<&str> = lines.next().expect("csv header").split(',').collect();
    let col = header
        .iter()
        .position(|h| *h == "bytes_sent")
        .expect("bytes_sent column in the metrics csv");
    let last = lines.last().expect("csv data row");
    last.split(',').nth(col).unwrap().parse().unwrap()
}

/// The acceptance criteria end to end through the real CLI: a 2-worker
/// `csopt launch --mode comm-sketch` run over a unix socket is
/// bit-identical (perplexities + checkpoint) to the 1-process reference
/// layout of the same replica count, and its metrics CSV records ≥ 4×
/// fewer gradient-exchange bytes than the same launch under
/// `--mode data`.
#[cfg(unix)]
#[test]
fn launch_cli_comm_sketch_is_deterministic_and_compressed() {
    let dir = std::env::temp_dir().join(format!("csopt_cs_launch_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("run.conf");
    std::fs::write(
        &cfg,
        "preset = tiny\nepochs = 1\nsteps = 6\neval.windows = 2\n\n\
         [optim]\nemb = \"cs-adam@v=2,w=48,clean=0.5/4\"\nsm = \"cs-adagrad@w=32\"\n",
    )
    .unwrap();
    let cfg = cfg.display().to_string();
    let path_of = |name: &str| dir.join(name).display().to_string();

    // 1-process reference layout (2 replica stripes, no transport)
    let (out_ref, _) = run_csopt(&[
        "run",
        &cfg,
        "--set",
        &format!(
            "dist.mode=comm-sketch,dist.replicas=2,checkpoint={}",
            path_of("ref.ck")
        ),
    ]);
    // 2-worker comm-sketch launch of the same run
    let (out_cs, _) = run_csopt(&[
        "launch",
        &cfg,
        "--workers",
        "2",
        "--mode",
        "comm-sketch",
        "--socket",
        &path_of("cs.sock"),
        "--set",
        &format!("checkpoint={},metrics={}", path_of("cs.ck"), path_of("cs.csv")),
    ]);
    let ppl_ref = ppl_readings(&out_ref);
    assert!(!ppl_ref.is_empty(), "no ppl readings in:\n{out_ref}");
    assert_eq!(
        ppl_ref,
        ppl_readings(&out_cs),
        "\n--- reference ---\n{out_ref}\n--- launch comm-sketch ---\n{out_cs}"
    );
    let a = Checkpoint::load(&path_of("ref.ck")).unwrap();
    let b = Checkpoint::load(&path_of("cs.ck")).unwrap();
    assert_eq!(a.scalar("step").unwrap(), b.scalar("step").unwrap());
    assert_eq!(a.blobs, b.blobs, "2-worker comm-sketch checkpoint differs from reference");

    // byte criterion: the same launch under dense data mode ships ≥ 4×
    // the gradient-exchange bytes per run (dist.sparse=false pins the
    // historical dense wire — the owned-rows default already shrinks
    // mode = data, which would understate the compressor's win)
    let (_out_data, _) = run_csopt(&[
        "launch",
        &cfg,
        "--workers",
        "2",
        "--mode",
        "data",
        "--socket",
        &path_of("data.sock"),
        "--set",
        &format!("dist.sparse=false,metrics={}", path_of("data.csv")),
    ]);
    let cs_bytes = final_bytes_sent(&path_of("cs.csv"));
    let data_bytes = final_bytes_sent(&path_of("data.csv"));
    assert!(cs_bytes > 0, "comm-sketch run recorded no transport traffic");
    assert!(
        data_bytes >= 4 * cs_bytes,
        "data mode sent {data_bytes} bytes, comm-sketch {cs_bytes} — less than 4×"
    );

    let _ = std::fs::remove_dir_all(dir);
}
