//! RunSpec/Session integration: the config-file path must be
//! bit-identical to the legacy `(emb, sm)` CLI construction, `--set`
//! overrides must take precedence, and checkpoints must record the
//! originating spec for resume-time comparison.

use csopt::config::lm_preset;
use csopt::exp::common::{build_trainer, corpus_for};
use csopt::optim::OptimSpec;
use csopt::train::checkpoint::Checkpoint;
use csopt::train::session::{RunSpec, Session};
use csopt::util::cli::Args;

fn no_args() -> Args {
    Args::parse(Vec::<String>::new(), &[]).unwrap()
}

#[test]
fn config_policy_matches_legacy_cli_pair_bitwise() {
    // legacy path: the (emb, sm) pair the CLI flags produce
    let emb = OptimSpec::parse("cs-adam@v=3,w=64").unwrap();
    let sm = OptimSpec::parse("adam").unwrap();
    let mut legacy = build_trainer("tiny", emb, sm, 1e-3, &no_args()).unwrap();

    // config path: the same run as a policy map in config-file text
    let config = "\
preset = tiny
epochs = 2
steps = 30

[optim]
emb = \"cs-adam@v=3,w=64\"
sm = \"adam\"
";
    let spec = RunSpec::parse(config).unwrap();
    let mut s = Session::build(&spec).unwrap();

    // identical corpora by construction (data.seed defaults to seed=42,
    // windows to steps+8, splits to 0.08/0.08 — the legacy cmd_train setup)
    let corpus = corpus_for(&lm_preset("tiny").unwrap(), 30 + 8, 42);
    let (train, valid, _) = corpus.split(0.08, 0.08);
    assert_eq!(train, &s.train[..]);
    assert_eq!(valid, &s.valid[..]);

    for epoch in 0..2 {
        let rl = legacy.train_epoch(train, 30).unwrap();
        let rc = s.epoch().unwrap();
        assert_eq!(
            rl.mean_loss.to_bits(),
            rc.mean_loss.to_bits(),
            "epoch {epoch}: legacy {} vs config {}",
            rl.mean_loss,
            rc.mean_loss
        );
    }
    assert_eq!(legacy.emb.params, s.trainer.emb.params);
    assert_eq!(legacy.sm.params, s.trainer.sm.params);
    assert_eq!(legacy.sm_bias.params, s.trainer.sm_bias.params);
    let vl = legacy.eval_ppl(valid, 8).unwrap();
    let vc = s.valid_ppl().unwrap();
    assert_eq!(vl.to_bits(), vc.to_bits());
}

#[test]
fn set_overrides_beat_config_file_values() {
    let config = "\
preset = tiny
epochs = 9
steps = 200
lr = 0.5

[optim]
emb = \"cs-adam\"
sm = \"adam\"
";
    let mut spec = RunSpec::parse(config).unwrap();
    spec.apply_sets("steps=5,epochs=1").unwrap();
    spec.apply_sets("optim.emb=cs-adam@v=2,w=16,lr=0.001").unwrap();
    assert_eq!(spec.steps, 5);
    assert_eq!(spec.epochs, 1);
    assert_eq!(spec.lr, 0.001);
    assert_eq!(spec.policy.resolve("emb").unwrap().to_string(), "cs-adam@v=2,w=16");
    // the overridden spec still builds and trains end-to-end
    let mut s = Session::build(&spec).unwrap();
    let summary = s.run().unwrap();
    assert_eq!(summary.epochs.len(), 1);
    assert_eq!(summary.epochs[0].steps, 5);
    assert!(summary.test_ppl.is_finite());
}

#[test]
fn policy_resolution_governs_session_layers() {
    let spec = RunSpec::parse(
        "preset = tiny\nsteps = 5\nepochs = 1\n\n[optim]\nemb = \"cs-adam\"\n* = \"sgd\"\n",
    )
    .unwrap();
    let s = Session::build(&spec).unwrap();
    // first match wins: emb gets the sketch, sm falls through to `*`
    assert_eq!(s.trainer.emb.opt.name(), "cs-adam");
    assert_eq!(s.trainer.sm.opt.name(), "sgd");
    assert_eq!(s.trainer.sm_bias.opt.memory_bytes(), 0);

    // unknown layer: no rule matches sm → actionable error
    let bad = RunSpec::parse("preset = tiny\n\n[optim]\nemb = \"cs-adam\"\n").unwrap();
    let err = format!("{:#}", Session::build(&bad).err().unwrap());
    assert!(err.contains("\"sm\""), "{err}");
}

#[test]
fn checkpoint_records_spec_and_resume_restores_state() {
    let dir = std::env::temp_dir().join(format!("csopt_runspec_{}", std::process::id()));
    let ck_path = dir.join("run.ck").display().to_string();
    let config = format!(
        "preset = tiny\nepochs = 1\nsteps = 8\ncheckpoint = {ck_path}\n\n\
         [optim]\nemb = \"adam\"\nsm = \"adam\"\n"
    );
    let spec = RunSpec::parse(&config).unwrap();
    let mut s = Session::build(&spec).unwrap();
    s.run().unwrap();

    // the canonical originating spec rides in the checkpoint
    let ck = Checkpoint::load(&ck_path).unwrap();
    assert_eq!(ck.str_opt("runspec"), Some(spec.trained_form().as_str()));
    assert_eq!(ck.scalar("step").unwrap(), 8);

    // resuming restores parameters and the step counter; a same-spec
    // resume round-trips without touching the trained state
    let mut resumed_spec = spec.clone();
    resumed_spec.checkpoint = None;
    resumed_spec.resume = Some(ck_path.clone());
    let mut resumed = Session::build(&resumed_spec).unwrap();
    assert_eq!(resumed.trainer.step, s.trainer.step);
    assert_eq!(resumed.trainer.emb.params, s.trainer.emb.params);
    assert_eq!(resumed.trainer.sm.params, s.trainer.sm.params);
    assert_eq!(resumed.trainer.sm_bias.params, s.trainer.sm_bias.params);
    let a = resumed.test_ppl().unwrap();
    let b = s.test_ppl().unwrap();
    assert_eq!(a.to_bits(), b.to_bits());

    // a mismatched spec must still resume (warn-only), not fail
    let mut drifted = resumed_spec.clone();
    drifted.lr = 0.9;
    let drifted_session = Session::build(&drifted).unwrap();
    assert_eq!(drifted_session.trainer.step, s.trainer.step);

    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn session_rejects_wrong_geometry_resume() {
    let dir = std::env::temp_dir().join(format!("csopt_runspec_geo_{}", std::process::id()));
    let ck_path = dir.join("run.ck").display().to_string();
    let config = format!(
        "preset = tiny\nepochs = 1\nsteps = 4\ncheckpoint = {ck_path}\n\n\
         [optim]\nemb = \"adam\"\nsm = \"adam\"\n"
    );
    let spec = RunSpec::parse(&config).unwrap();
    Session::build(&spec).unwrap().run().unwrap();

    // resuming a tiny checkpoint into a wt2-sized run is a hard error
    // (parameter shapes cannot transfer), with the blob named
    let mut wrong = spec.clone();
    wrong.preset = "wt2".to_string();
    wrong.checkpoint = None;
    wrong.resume = Some(ck_path);
    let err = format!("{:#}", Session::build(&wrong).err().unwrap());
    assert!(err.contains("emb.params"), "{err}");

    let _ = std::fs::remove_dir_all(dir);
}
