//! Data-parallel training equivalence suite (DESIGN.md §10).
//!
//! The §10 claim is the same shape as §9's, one seam up: N workers
//! training **distinct batches** with gradient all-reduce are bitwise
//! identical — parameters, losses, perplexities, checkpoints — to one
//! process training the N×-larger global batch (the `mode = data`,
//! `workers = 1` layout that owns every replica stripe).
//!
//! * Property legs pin the striping substrate: `width_partition` /
//!   `stream_stripe` partitions are disjoint, exhaustive and balanced
//!   over `(len, world)` grids, and the replica-strided candidate
//!   sampler keeps replica 0 bit-identical to the legacy sampler.
//! * Trainer legs drive real multi-rank worlds over the in-memory
//!   transport (threads) in `data` and `hybrid` mode, comparing full
//!   trajectories bitwise against the single-process global-batch run.
//! * The subprocess legs run the actual `csopt launch --mode data` /
//!   `--mode hybrid` CLI and prove the acceptance criterion end to end,
//!   including checkpoint resume across `{mode, workers}` layouts.

use std::thread;

use csopt::comm::{mem_world, DistCtx};
use csopt::data::corpus::SyntheticCorpus;
use csopt::sketch::plan::width_partition;
use csopt::train::checkpoint::Checkpoint;
use csopt::train::sampler::{stream_stripe, CandidateSampler};
use csopt::train::session::{RunSpec, Session};
use csopt::util::proptest::check;

// ---------------------------------------------------------------------------
// property legs (no new deps — the crate's own seeded proptest helper)

/// Partitions/stripes are disjoint, exhaustive, ordered and balanced for
/// every `(len, world)` in a randomized grid, and `world = 1` reduces to
/// the legacy whole-range path.
#[test]
fn partition_and_stripe_properties() {
    check("width-partition-grid", 300, 0xA11, |rng| {
        let len = rng.below(4096);
        let world = 1 + rng.below(9);
        let mut cursor = 0usize;
        let (mut min_sz, mut max_sz) = (usize::MAX, 0usize);
        for r in 0..world {
            let (wp, sp) = (width_partition(len, world, r), stream_stripe(len, world, r));
            if wp != sp {
                return Err(format!("stripe {sp:?} != partition {wp:?} (len={len} world={world})"));
            }
            let (lo, hi) = wp;
            if lo != cursor || hi < lo || hi > len {
                return Err(format!(
                    "range [{lo}, {hi}) breaks the tiling at cursor {cursor} \
                     (len={len} world={world} r={r})"
                ));
            }
            min_sz = min_sz.min(hi - lo);
            max_sz = max_sz.max(hi - lo);
            cursor = hi;
        }
        if cursor != len {
            return Err(format!("stripes cover [0, {cursor}) of [0, {len}) — not exhaustive"));
        }
        if max_sz - min_sz > 1 {
            return Err(format!(
                "unbalanced stripes: sizes span [{min_sz}, {max_sz}] (len={len} world={world})"
            ));
        }
        if stream_stripe(len, 1, 0) != (0, len) {
            return Err(format!("world=1 must be the legacy whole stream (len={len})"));
        }
        Ok(())
    });
}

/// Replica 0's sampler is the legacy sampler bit-for-bit under any seed;
/// other replicas stride onto decorrelated streams.
#[test]
fn sampler_striding_properties() {
    check("sampler-replica-striding", 60, 0xB22, |rng| {
        let seed = rng.next_u64();
        let mut legacy = CandidateSampler::new(512, 32, seed);
        let mut r0 = CandidateSampler::for_replica(512, 32, seed, 0);
        for _ in 0..3 {
            let targets: Vec<u32> = (0..4).map(|_| rng.below(512) as u32).collect();
            let (a, b) = (legacy.sample(&targets), r0.sample(&targets));
            if a.ids != b.ids || a.ytgt != b.ytgt {
                return Err(format!("replica 0 diverged from legacy under seed {seed:#x}"));
            }
        }
        let mut r1 = CandidateSampler::for_replica(512, 32, seed, 1);
        let mut r2 = CandidateSampler::for_replica(512, 32, seed, 2);
        let (a, b) = (r1.sample(&[7]), r2.sample(&[7]));
        if a.ids == b.ids {
            return Err(format!("replicas 1 and 2 drew identical negatives (seed {seed:#x})"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// trainer legs (in-memory transport, real multi-rank worlds)

fn dp_spec(extra_dist: &str) -> RunSpec {
    let text = format!(
        "preset = tiny\nepochs = 1\nsteps = 8\neval.windows = 2\n\n\
         [optim]\nemb = \"cs-adam@v=2,w=48,clean=0.5/4\"\nsm = \"cs-adagrad@w=32\"\n\n\
         [dist]\n{extra_dist}"
    );
    RunSpec::parse(&text).unwrap()
}

/// One rank's full observable state after an epoch.
#[derive(PartialEq)]
struct Snapshot {
    loss_bits: u64,
    emb: Vec<f32>,
    sm: Vec<f32>,
    bias: Vec<f32>,
    flat: Vec<f32>,
    ppl_bits: u64,
}

fn run_rank(spec: &RunSpec, ctx: Option<&DistCtx>, train: &[u32], valid: &[u32]) -> Snapshot {
    let mut tr = Session::build_trainer_dist(spec, ctx).unwrap();
    let r = tr.train_epoch(train, 8).unwrap();
    let ppl = tr.eval_ppl(valid, 2).unwrap();
    let mut flat = Vec::new();
    tr.engine.pack_flat(&mut flat);
    Snapshot {
        loss_bits: r.mean_loss.to_bits(),
        emb: tr.emb.params.clone(),
        sm: tr.sm.params.clone(),
        bias: tr.sm_bias.params.clone(),
        flat,
        ppl_bits: ppl.to_bits(),
    }
}

fn assert_snapshots_match(a: &Snapshot, b: &Snapshot, what: &str) {
    assert_eq!(a.loss_bits, b.loss_bits, "{what}: mean loss diverged");
    assert_eq!(a.emb, b.emb, "{what}: emb params diverged");
    assert_eq!(a.sm, b.sm, "{what}: sm params diverged");
    assert_eq!(a.bias, b.bias, "{what}: bias params diverged");
    assert_eq!(a.flat, b.flat, "{what}: trunk params diverged");
    assert_eq!(a.ppl_bits, b.ppl_bits, "{what}: valid ppl diverged");
}

/// `mode = data`: multi-worker trajectories over the mem transport are
/// bit-identical to the single-process global-batch run — every rank,
/// for both the `replicas == workers` and `replicas > workers`
/// (multi-stripe-per-rank) layouts.
#[test]
fn data_parallel_trainer_matches_global_batch_bitwise() {
    let corpus = SyntheticCorpus::generate(512, 60_000, 1.05, 0.6, 11);
    let (train, valid, _) = corpus.split(0.08, 0.05);

    for (workers, replicas) in [(2usize, 2usize), (2, 4), (3, 3)] {
        let reference = run_rank(
            &dp_spec(&format!("mode = data\nreplicas = {replicas}\n")),
            None,
            train,
            valid,
        );
        let outs: Vec<Snapshot> = thread::scope(|s| {
            let handles: Vec<_> = mem_world(workers)
                .into_iter()
                .enumerate()
                .map(|(rank, ep)| {
                    let mut spec = dp_spec(&format!(
                        "mode = data\nrank = {rank}\nworkers = {workers}\n\
                         replicas = {replicas}\n"
                    ));
                    spec.dist.as_mut().unwrap().rank = rank;
                    s.spawn(move || {
                        let ctx = DistCtx::new(rank, workers, ep);
                        run_rank(&spec, Some(&ctx), train, valid)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (rank, out) in outs.iter().enumerate() {
            assert_snapshots_match(
                out,
                &reference,
                &format!("data workers={workers} replicas={replicas} rank={rank}"),
            );
        }
    }
}

/// DESIGN.md §14: every `{sparse, overlap}` wire/schedule setting of a
/// 2-rank `mode = data` world reproduces the single-process global-batch
/// reference bit-for-bit — the owned-rows exchange is a pure copy-merge
/// and overlap only moves when the exchange wait happens — and the
/// default owned-rows wire ships *under half* the dense `sparse = false`
/// bytes on tiny's activity profile (≤ 32 + 128 active rows of 512 per
/// replica window).
#[test]
fn sparse_overlap_layouts_match_reference_and_shrink_wire() {
    let corpus = SyntheticCorpus::generate(512, 60_000, 1.05, 0.6, 14);
    let (train, valid, _) = corpus.split(0.08, 0.05);
    let reference = run_rank(&dp_spec("mode = data\nreplicas = 2\n"), None, train, valid);

    let mut sent_by_cfg: Vec<(bool, bool, u64)> = Vec::new();
    for (sparse, overlap) in [(false, false), (true, false), (false, true), (true, true)] {
        let workers = 2usize;
        let outs: Vec<(Snapshot, u64)> = thread::scope(|s| {
            let handles: Vec<_> = mem_world(workers)
                .into_iter()
                .enumerate()
                .map(|(rank, ep)| {
                    let mut spec = dp_spec(&format!(
                        "mode = data\nrank = {rank}\nworkers = {workers}\nreplicas = 2\n\
                         sparse = {sparse}\noverlap = {overlap}\n"
                    ));
                    spec.dist.as_mut().unwrap().rank = rank;
                    s.spawn(move || {
                        let ctx = DistCtx::new(rank, workers, ep);
                        let snap = run_rank(&spec, Some(&ctx), train, valid);
                        let sent = ctx.comm().lock().unwrap().bytes_sent();
                        (snap, sent)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (rank, (out, _)) in outs.iter().enumerate() {
            assert_snapshots_match(
                out,
                &reference,
                &format!("data sparse={sparse} overlap={overlap} rank={rank}"),
            );
        }
        sent_by_cfg.push((sparse, overlap, outs[0].1));
    }
    let dense_sent = sent_by_cfg.iter().find(|(s, _, _)| !s).unwrap().2;
    for &(sparse, overlap, sent) in &sent_by_cfg {
        if sparse {
            assert!(
                sent * 2 < dense_sent,
                "owned-rows wire sent {sent} bytes (overlap={overlap}) vs dense \
                 {dense_sent} — expected under half"
            );
        } else {
            assert_eq!(
                sent, dense_sent,
                "dense wire bytes must not depend on overlap={overlap}"
            );
        }
    }
}

/// `mode = hybrid`: distinct batches *and* width-partitioned sketches at
/// once — still bit-identical to the single-process global-batch run
/// (which uses in-process `shards = 2` execution sharding, itself
/// equivalence-pinned by §5), and the per-rank sketch shares still tile
/// the single-process footprint exactly once.
#[test]
fn hybrid_trainer_matches_global_batch_bitwise() {
    let corpus = SyntheticCorpus::generate(512, 60_000, 1.05, 0.6, 12);
    let (train, valid, _) = corpus.split(0.08, 0.05);

    let mut ref_spec = dp_spec("mode = data\nreplicas = 2\n");
    ref_spec.shards = 2;
    let mut ref_tr = Session::build_trainer_dist(&ref_spec, None).unwrap();
    let ref_sketch_bytes = ref_tr.emb.opt.memory_bytes() + ref_tr.sm.opt.memory_bytes();
    let r = ref_tr.train_epoch(train, 8).unwrap();
    let ref_ppl = ref_tr.eval_ppl(valid, 2).unwrap();

    let workers = 2usize;
    let outs: Vec<(Snapshot, usize)> = thread::scope(|s| {
        let handles: Vec<_> = mem_world(workers)
            .into_iter()
            .enumerate()
            .map(|(rank, ep)| {
                let spec = {
                    let mut spec = dp_spec(&format!(
                        "mode = hybrid\nrank = {rank}\nworkers = {workers}\n"
                    ));
                    spec.dist.as_mut().unwrap().rank = rank;
                    spec
                };
                s.spawn(move || {
                    let ctx = DistCtx::new(rank, workers, ep);
                    let mut tr = Session::build_trainer_dist(&spec, Some(&ctx)).unwrap();
                    let sketch_bytes = tr.emb.opt.memory_bytes() + tr.sm.opt.memory_bytes();
                    let rep = tr.train_epoch(train, 8).unwrap();
                    let ppl = tr.eval_ppl(valid, 2).unwrap();
                    let mut flat = Vec::new();
                    tr.engine.pack_flat(&mut flat);
                    (
                        Snapshot {
                            loss_bits: rep.mean_loss.to_bits(),
                            emb: tr.emb.params.clone(),
                            sm: tr.sm.params.clone(),
                            bias: tr.sm_bias.params.clone(),
                            flat,
                            ppl_bits: ppl.to_bits(),
                        },
                        sketch_bytes,
                    )
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let reference = Snapshot {
        loss_bits: r.mean_loss.to_bits(),
        emb: ref_tr.emb.params.clone(),
        sm: ref_tr.sm.params.clone(),
        bias: ref_tr.sm_bias.params.clone(),
        flat: {
            let mut flat = Vec::new();
            ref_tr.engine.pack_flat(&mut flat);
            flat
        },
        ppl_bits: ref_ppl.to_bits(),
    };
    let mut total_sketch_bytes = 0usize;
    for (rank, (out, sketch_bytes)) in outs.iter().enumerate() {
        assert_snapshots_match(out, &reference, &format!("hybrid rank={rank}"));
        total_sketch_bytes += sketch_bytes;
    }
    // hybrid keeps §9's memory win: per-rank sketch shares sum to the
    // single-process footprint
    assert_eq!(total_sketch_bytes, ref_sketch_bytes);
}

/// Checkpoints are layout-independent in data mode too: a 2-rank
/// `mode = data` run's checkpoint is byte-identical to the 1-process
/// global-batch run's, and both resume to bitwise-identical
/// continuations.
#[test]
fn data_checkpoint_is_layout_independent() {
    let dir = std::env::temp_dir().join(format!("csopt_dp_ck_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ck_single = dir.join("single.ck").display().to_string();
    let ck_dist = dir.join("dist.ck").display().to_string();
    let corpus = SyntheticCorpus::generate(512, 60_000, 1.05, 0.6, 13);
    let (train, _, _) = corpus.split(0.08, 0.05);

    let ref_spec = dp_spec("mode = data\nreplicas = 2\n");
    // 1-process global-batch checkpoint
    {
        let mut tr = Session::build_trainer_dist(&ref_spec, None).unwrap();
        tr.train_epoch(train, 8).unwrap();
        let mut s = Session::build(&ref_spec).unwrap();
        s.trainer = tr;
        s.save_checkpoint(&ck_single).unwrap();
    }
    // 2-rank world writes rank 0's view of the same run
    let workers = 2usize;
    thread::scope(|scope| {
        let handles: Vec<_> = mem_world(workers)
            .into_iter()
            .enumerate()
            .map(|(rank, ep)| {
                let mut spec = dp_spec(&format!(
                    "mode = data\nrank = {rank}\nworkers = {workers}\nreplicas = 2\n"
                ));
                spec.dist.as_mut().unwrap().rank = rank;
                let (ck_dist, ref_spec) = (ck_dist.clone(), ref_spec.clone());
                scope.spawn(move || {
                    let ctx = DistCtx::new(rank, workers, ep);
                    let mut tr = Session::build_trainer_dist(&spec, Some(&ctx)).unwrap();
                    tr.train_epoch(train, 8).unwrap();
                    if rank == 0 {
                        // record under the reference layout's spec: the
                        // trained_form is identical (placement stripped)
                        let mut s = Session::build(&ref_spec).unwrap();
                        s.trainer = tr;
                        s.save_checkpoint(&ck_dist).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });

    let a = Checkpoint::load(&ck_single).unwrap();
    let b = Checkpoint::load(&ck_dist).unwrap();
    assert_eq!(a.scalar("step").unwrap(), b.scalar("step").unwrap());
    assert_eq!(a.blobs, b.blobs, "2-rank data checkpoint differs from global-batch run's");

    // both resume into bitwise-identical single-process continuations
    let mut conts: Vec<(u64, Vec<f32>)> = Vec::new();
    for ck in [&ck_dist, &ck_single] {
        let mut rspec = dp_spec("mode = data\nreplicas = 2\n");
        rspec.resume = Some(ck.clone());
        let mut session = Session::build(&rspec).unwrap();
        let r = session.epoch().unwrap();
        conts.push((r.mean_loss.to_bits(), session.trainer.emb.params.clone()));
    }
    assert_eq!(conts[0].0, conts[1].0, "post-resume loss diverged");
    assert_eq!(conts[0].1, conts[1].1, "post-resume emb params diverged");

    let _ = std::fs::remove_dir_all(dir);
}

// ---------------------------------------------------------------------------
// CLI legs (the real `csopt launch --mode data|hybrid` binary)

/// Pull the `valid ppl <x>` / `final test ppl: <x>` readings out of a
/// run's stdout (timing fields vary run to run, the ppl numbers must
/// not).
fn ppl_readings(stdout: &str) -> Vec<String> {
    let mut out = Vec::new();
    for line in stdout.lines() {
        if let Some(ix) = line.find("valid ppl ") {
            let rest = &line[ix + "valid ppl ".len()..];
            out.push(rest.split(',').next().unwrap().trim().to_string());
        }
        if let Some(rest) = line.strip_prefix("final test ppl: ") {
            out.push(rest.trim().to_string());
        }
    }
    out
}

fn run_csopt(args: &[&str]) -> (String, String) {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_csopt"))
        .args(args)
        .output()
        .expect("running csopt");
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    let stderr = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(out.status.success(), "csopt {args:?} failed:\n{stdout}\n{stderr}");
    (stdout, stderr)
}

fn assert_checkpoints_equal(a_path: &str, b_path: &str, what: &str) {
    let a = Checkpoint::load(a_path).unwrap();
    let b = Checkpoint::load(b_path).unwrap();
    assert_eq!(a.scalar("step").unwrap(), b.scalar("step").unwrap(), "{what}: step");
    assert_eq!(
        a.blobs.keys().collect::<Vec<_>>(),
        b.blobs.keys().collect::<Vec<_>>(),
        "{what}: blob names"
    );
    for (name, blob) in &a.blobs {
        assert_eq!(blob, &b.blobs[name], "{what}: checkpoint blob {name} differs");
    }
}

/// The acceptance criterion end to end through the real CLI: a 2-worker
/// `csopt launch --mode data` run (rank 0 + one forked worker over a
/// unix socket, distinct batch stripes) is bit-identical — final params
/// and valid/test perplexities — to the single-process global-batch run
/// of the same config; `--mode hybrid` matches the same reference with
/// `shards = 2` execution sharding; and checkpoints resume across
/// `{mode, workers}` layouts with bitwise-identical continuations.
#[cfg(unix)]
#[test]
fn launch_cli_data_and_hybrid_match_global_batch() {
    let dir = std::env::temp_dir().join(format!("csopt_dp_launch_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("run.conf");
    std::fs::write(
        &cfg,
        "preset = tiny\nepochs = 1\nsteps = 6\neval.windows = 2\n\n\
         [optim]\nemb = \"cs-adam@v=2,w=48,clean=0.5/4\"\nsm = \"cs-adagrad@w=32\"\n",
    )
    .unwrap();
    let cfg = cfg.display().to_string();
    let path_of = |name: &str| dir.join(name).display().to_string();
    let (ck_ref, ck_data, ck_hybrid) = (path_of("ref.ck"), path_of("data.ck"), path_of("hy.ck"));

    // single-process global-batch reference (2 replica stripes, 1 process)
    let (out_ref, _) = run_csopt(&[
        "run",
        &cfg,
        "--set",
        &format!("dist.mode=data,dist.replicas=2,checkpoint={ck_ref}"),
    ]);
    // 2-worker data-parallel launch of the same global batch
    let (out_data, _) = run_csopt(&[
        "launch",
        &cfg,
        "--workers",
        "2",
        "--mode",
        "data",
        "--socket",
        &path_of("data.sock"),
        "--set",
        &format!("checkpoint={ck_data}"),
    ]);
    let ppl_ref = ppl_readings(&out_ref);
    assert!(!ppl_ref.is_empty(), "no ppl readings in:\n{out_ref}");
    assert_eq!(
        ppl_ref,
        ppl_readings(&out_data),
        "\n--- reference ---\n{out_ref}\n--- launch data ---\n{out_data}"
    );
    assert_checkpoints_equal(&ck_ref, &ck_data, "data vs global-batch");

    // hybrid launch vs the shards=2 global-batch reference
    let (out_ref2, _) = run_csopt(&[
        "run",
        &cfg,
        "--set",
        &format!("shards=2,dist.mode=data,dist.replicas=2,checkpoint={}", path_of("ref2.ck")),
    ]);
    let (out_hybrid, _) = run_csopt(&[
        "launch",
        &cfg,
        "--workers",
        "2",
        "--mode",
        "hybrid",
        "--socket",
        &path_of("hy.sock"),
        "--set",
        &format!("checkpoint={ck_hybrid}"),
    ]);
    assert_eq!(
        ppl_readings(&out_ref2),
        ppl_readings(&out_hybrid),
        "\n--- reference shards=2 ---\n{out_ref2}\n--- launch hybrid ---\n{out_hybrid}"
    );
    assert_checkpoints_equal(&path_of("ref2.ck"), &ck_hybrid, "hybrid vs shards=2 global-batch");

    // cross-layout resume: the 2-worker checkpoint resumed in 1 process,
    // the 1-process checkpoint resumed across 2 workers, and the data
    // checkpoint resumed under hybrid must all continue identically
    let (cont_a, _) = run_csopt(&[
        "run",
        &cfg,
        "--set",
        &format!(
            "dist.mode=data,dist.replicas=2,resume={ck_data},checkpoint={}",
            path_of("cont_a.ck")
        ),
    ]);
    let (cont_b, _) = run_csopt(&[
        "launch",
        &cfg,
        "--workers",
        "2",
        "--mode",
        "data",
        "--socket",
        &path_of("cont.sock"),
        "--set",
        &format!("resume={ck_ref},checkpoint={}", path_of("cont_b.ck")),
    ]);
    let (cont_c, _) = run_csopt(&[
        "launch",
        &cfg,
        "--workers",
        "2",
        "--mode",
        "hybrid",
        "--socket",
        &path_of("cont_c.sock"),
        "--set",
        &format!("resume={ck_data},checkpoint={}", path_of("cont_c.ck")),
    ]);
    assert_eq!(ppl_readings(&cont_a), ppl_readings(&cont_b));
    assert_eq!(ppl_readings(&cont_a), ppl_readings(&cont_c));
    assert_checkpoints_equal(&path_of("cont_a.ck"), &path_of("cont_b.ck"), "resume a vs b");
    assert_checkpoints_equal(&path_of("cont_a.ck"), &path_of("cont_c.ck"), "resume a vs c");

    let _ = std::fs::remove_dir_all(dir);
}
