//! Shared helpers for the integration suites.
//!
//! Each integration binary compiles its own copy of this module, so not
//! every binary uses every helper.
#![allow(dead_code)]

use std::time::Duration;

/// Run `f` on a worker thread and panic if it has not finished within
/// `deadline` — the timeout guard the fault-injection suite runs under,
/// so a regression back to hanging sockets fails the test in seconds
/// instead of stalling the whole `cargo test` job. The hung thread is
/// leaked (it is stuck in a syscall); the panic is what CI sees.
pub fn with_deadline<T: Send + 'static>(
    deadline: Duration,
    f: impl FnOnce() -> T + Send + 'static,
) -> T {
    use std::sync::mpsc::RecvTimeoutError;
    let (tx, rx) = std::sync::mpsc::channel();
    let name = std::thread::current().name().unwrap_or("test").to_string();
    std::thread::Builder::new()
        .name(format!("{name}-deadline"))
        .spawn(move || {
            // ignore the send error if the receiver already timed out
            let _ = tx.send(f());
        })
        .expect("spawning deadline worker");
    match rx.recv_timeout(deadline) {
        Ok(v) => v,
        // worker panicked before sending: the real assertion failure is
        // in its panic output — don't misreport it as a hang
        Err(RecvTimeoutError::Disconnected) => {
            panic!("test body panicked — see the worker thread's panic above")
        }
        Err(RecvTimeoutError::Timeout) => {
            panic!("test body exceeded its {deadline:?} deadline — likely a hang")
        }
    }
}

/// A deliberately misbehaving raw-socket peer for the transport
/// fault-injection suite (`comm/uds.rs` + `comm/tcp.rs`): speaks just
/// enough of the §9 wire format (`u32 header_len | JSON header |
/// raw-f32 payload`) to get past the handshake, then violates the
/// protocol on purpose. The frame writers are generic over `Write`, so
/// one rogue covers both socket families.
pub mod rogue {
    use std::io::Write;
    use std::net::TcpStream;
    use std::time::{Duration, Instant};

    /// One rogue connection, either socket family behind a `Write` face.
    pub enum Conn {
        #[cfg(unix)]
        Uds(std::os::unix::net::UnixStream),
        Tcp(TcpStream),
    }

    impl Write for Conn {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            match self {
                #[cfg(unix)]
                Conn::Uds(s) => s.write(buf),
                Conn::Tcp(s) => s.write(buf),
            }
        }
        fn flush(&mut self) -> std::io::Result<()> {
            match self {
                #[cfg(unix)]
                Conn::Uds(s) => s.flush(),
                Conn::Tcp(s) => s.flush(),
            }
        }
    }

    /// Connect to a coordinator endpoint — `host:port` → TCP, anything
    /// else → unix-domain socket — retrying while it comes up.
    pub fn connect(ep: &str, timeout: Duration) -> Conn {
        let deadline = Instant::now() + timeout;
        loop {
            let attempt: std::io::Result<Conn> = if ep.contains(':') {
                TcpStream::connect(ep).map(Conn::Tcp)
            } else {
                #[cfg(unix)]
                {
                    std::os::unix::net::UnixStream::connect(ep).map(Conn::Uds)
                }
                #[cfg(not(unix))]
                {
                    panic!("unix-socket endpoint {ep} on a non-unix platform")
                }
            };
            match attempt {
                Ok(s) => return s,
                Err(e) => {
                    assert!(
                        Instant::now() <= deadline,
                        "rogue peer: coordinator endpoint {ep} never came up: {e}"
                    );
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
        }
    }

    /// Write one well-formed frame: `header` must be the JSON header
    /// text (the real transport always includes an `"n"` field).
    pub fn send_frame<W: Write>(stream: &mut W, header: &str, payload: &[f32]) {
        stream.write_all(&(header.len() as u32).to_le_bytes()).unwrap();
        stream.write_all(header.as_bytes()).unwrap();
        for x in payload {
            stream.write_all(&x.to_le_bytes()).unwrap();
        }
        stream.flush().unwrap();
    }

    /// A valid hello frame for `rank` of `world`.
    pub fn send_hello<W: Write>(stream: &mut W, rank: usize, world: usize) {
        send_frame(
            stream,
            &format!("{{\"op\":\"hello\",\"n\":0,\"rank\":{rank},\"world\":{world}}}"),
            &[],
        );
    }

    /// A frame whose length prefix promises `claimed` header bytes but
    /// ships only `sent` of them (the truncated-frame fault).
    pub fn send_truncated_header<W: Write>(stream: &mut W, claimed: u32, sent: usize) {
        stream.write_all(&claimed.to_le_bytes()).unwrap();
        stream.write_all(&vec![b'{'; sent]).unwrap();
        stream.flush().unwrap();
    }

    /// A hand-crafted owned-rows frame (DESIGN.md §14 wire format):
    /// `u64` row ids ride between the JSON header and the f32 payload.
    /// The header text is caller-supplied so a rogue can lie about any
    /// field — row count, geometry, payload size — independently of the
    /// bytes it actually ships.
    pub fn send_rows_frame<W: Write>(
        stream: &mut W,
        header: &str,
        ids: &[u64],
        payload: &[f32],
    ) {
        stream.write_all(&(header.len() as u32).to_le_bytes()).unwrap();
        stream.write_all(header.as_bytes()).unwrap();
        for &id in ids {
            stream.write_all(&id.to_le_bytes()).unwrap();
        }
        for x in payload {
            stream.write_all(&x.to_le_bytes()).unwrap();
        }
        stream.flush().unwrap();
    }
}

/// Tolerance harness shared by the lossy-compression suites (comm-sketch
/// wire, quantized sketch cells): a single perplexity-factor gate, plus a
/// trajectory reporter that pinpoints *where* two runs part ways instead
/// of leaving a bare boolean failure.
pub mod tolerance {
    /// Assert a lossy run still trains: `got` perplexity within
    /// `factor`× of the `reference` run's. Both must be finite — a NaN
    /// ppl comparing `false` must fail, not pass.
    pub fn assert_ppl_within(what: &str, got: f64, reference: f64, factor: f64) {
        assert!(
            got.is_finite() && reference.is_finite(),
            "{what}: non-finite perplexity (got {got}, reference {reference})"
        );
        assert!(
            got <= reference * factor,
            "{what}: ppl {got:.3} exceeds {factor}× the reference ppl {reference:.3} \
             (allowed ≤ {:.3})",
            reference * factor
        );
    }

    /// Where two per-step state trajectories diverge. `steps` are
    /// parallel sequences of equal-length f32 snapshots (params, sketch
    /// cells, …).
    pub struct TrajectoryReport {
        /// First step whose snapshots differ bitwise, if any.
        pub first_divergent_step: Option<usize>,
        /// Largest |a−b| across all steps.
        pub max_abs_err: f32,
        /// `(step, flat index)` of that largest error.
        pub max_at: (usize, usize),
    }

    impl TrajectoryReport {
        pub fn bitwise_identical(&self) -> bool {
            self.first_divergent_step.is_none()
        }

        /// Human-readable one-liner for assertion messages.
        pub fn describe(&self) -> String {
            match self.first_divergent_step {
                None => "trajectories bitwise-identical".to_string(),
                Some(s) => format!(
                    "trajectories first diverge at step {s}; max |err| {:.3e} at \
                     step {} index {}",
                    self.max_abs_err, self.max_at.0, self.max_at.1
                ),
            }
        }
    }

    /// Compare two trajectories step by step. Panics on shape mismatch —
    /// that is a harness bug, not a tolerance question.
    pub fn compare_trajectories(a: &[Vec<f32>], b: &[Vec<f32>]) -> TrajectoryReport {
        assert_eq!(a.len(), b.len(), "trajectory step counts differ");
        let mut report = TrajectoryReport {
            first_divergent_step: None,
            max_abs_err: 0.0,
            max_at: (0, 0),
        };
        for (step, (xa, xb)) in a.iter().zip(b).enumerate() {
            assert_eq!(xa.len(), xb.len(), "step {step}: snapshot lengths differ");
            let mut step_diverged = false;
            for (i, (&va, &vb)) in xa.iter().zip(xb).enumerate() {
                if va.to_bits() != vb.to_bits() {
                    step_diverged = true;
                    let err = (va - vb).abs();
                    // NaN-vs-value divergences report as infinite error
                    let err = if err.is_nan() { f32::INFINITY } else { err };
                    if err > report.max_abs_err {
                        report.max_abs_err = err;
                        report.max_at = (step, i);
                    }
                }
            }
            if step_diverged && report.first_divergent_step.is_none() {
                report.first_divergent_step = Some(step);
            }
        }
        report
    }

    /// Assert two trajectories are bitwise-identical, reporting the first
    /// divergence point when they are not.
    pub fn assert_trajectories_identical(what: &str, a: &[Vec<f32>], b: &[Vec<f32>]) {
        let report = compare_trajectories(a, b);
        assert!(report.bitwise_identical(), "{what}: {}", report.describe());
    }
}

/// Open the artifact runtime, or return `None` when the XLA leg is
/// legitimately absent in this environment — the vendored stub `xla`
/// crate, or no `make artifacts` output (missing `manifest.json`). Any
/// *other* `Runtime::open` failure (manifest parse regression, real
/// backend breakage) panics so the signal is not lost behind a skip.
pub fn runtime_or_skip() -> Option<csopt::runtime::Runtime> {
    let dir = std::env::var("CSOPT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    match csopt::runtime::Runtime::open(dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            let msg = format!("{e:#}");
            assert!(
                msg.contains("vendored stub") || msg.contains("manifest.json"),
                "Runtime::open failed for an unexpected reason: {msg}"
            );
            eprintln!("skipping test: XLA leg unavailable ({msg})");
            None
        }
    }
}
