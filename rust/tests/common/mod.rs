//! Shared helpers for the integration suites.

/// Open the artifact runtime, or return `None` when the XLA leg is
/// legitimately absent in this environment — the vendored stub `xla`
/// crate, or no `make artifacts` output (missing `manifest.json`). Any
/// *other* `Runtime::open` failure (manifest parse regression, real
/// backend breakage) panics so the signal is not lost behind a skip.
pub fn runtime_or_skip() -> Option<csopt::runtime::Runtime> {
    let dir = std::env::var("CSOPT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    match csopt::runtime::Runtime::open(dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            let msg = format!("{e:#}");
            assert!(
                msg.contains("vendored stub") || msg.contains("manifest.json"),
                "Runtime::open failed for an unexpected reason: {msg}"
            );
            eprintln!("skipping test: XLA leg unavailable ({msg})");
            None
        }
    }
}
