//! Plan/shard equivalence suite (DESIGN.md §2/§5 invariants).
//!
//! The scalar baseline below is *re-implemented from `SketchHasher`
//! primitives* — it mirrors the pre-plan per-id loops — so these tests
//! keep guarding the refactored execution paths even though the id-based
//! sketch methods are now wrappers over the same plan core. Everything is
//! compared **bit-exactly** (`==` on f32 buffers), because hash-once plans
//! and sharding are pure execution-policy changes: they must not move a
//! single ulp.

use csopt::sketch::{CountMinSketch, CountSketch, SketchHasher, SketchPlan};
use csopt::util::proptest::check;
use csopt::util::rng::Rng;

/// Scalar count-sketch UPDATE exactly as the pre-plan implementation:
/// per depth, per item, hash and scatter-add the signed delta.
fn scalar_cs_update(data: &mut [f32], h: &SketchHasher, d: usize, ids: &[u64], deltas: &[f32]) {
    let w = h.width();
    for j in 0..h.depth() {
        for (t, &id) in ids.iter().enumerate() {
            let (b, s) = h.bucket_sign(j, id);
            let row = &mut data[(j * w + b) * d..(j * w + b + 1) * d];
            let delta = &deltas[t * d..(t + 1) * d];
            if s >= 0.0 {
                for (r, &x) in row.iter_mut().zip(delta) {
                    *r += x;
                }
            } else {
                for (r, &x) in row.iter_mut().zip(delta) {
                    *r -= x;
                }
            }
        }
    }
}

/// Scalar count-sketch QUERY: signed median over depth, per item.
fn scalar_cs_query(data: &[f32], h: &SketchHasher, d: usize, ids: &[u64], out: &mut [f32]) {
    let w = h.width();
    let v = h.depth();
    let mut vals = vec![0.0f32; v];
    for (t, &id) in ids.iter().enumerate() {
        for i in 0..d {
            for j in 0..v {
                let (b, s) = h.bucket_sign(j, id);
                vals[j] = s * data[(j * w + b) * d + i];
            }
            // median identical to the production kernels: sort + middle
            // (v ≤ 3 there is a min/max network computing the same value)
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            out[t * d + i] = if v % 2 == 1 {
                vals[v / 2]
            } else {
                0.5 * (vals[v / 2 - 1] + vals[v / 2])
            };
        }
    }
}

/// Scalar count-min UPDATE/QUERY (unsigned add, min over depth).
fn scalar_cms_update(data: &mut [f32], h: &SketchHasher, d: usize, ids: &[u64], deltas: &[f32]) {
    let w = h.width();
    for j in 0..h.depth() {
        for (t, &id) in ids.iter().enumerate() {
            let b = h.bucket(j, id);
            let row = &mut data[(j * w + b) * d..(j * w + b + 1) * d];
            for (r, &x) in row.iter_mut().zip(&deltas[t * d..(t + 1) * d]) {
                *r += x;
            }
        }
    }
}

fn scalar_cms_query(data: &[f32], h: &SketchHasher, d: usize, ids: &[u64], out: &mut [f32]) {
    let w = h.width();
    for (t, &id) in ids.iter().enumerate() {
        for i in 0..d {
            let mut m = f32::INFINITY;
            for j in 0..h.depth() {
                let b = h.bucket(j, id);
                let x = data[(j * w + b) * d + i];
                if x < m {
                    m = x;
                }
            }
            out[t * d + i] = m;
        }
    }
}

/// The (v, w, d, k, shards) grid of the issue's acceptance criterion,
/// mixing tiny degenerate geometries with paper-adjacent ones.
fn grid() -> Vec<(usize, usize, usize, usize, usize)> {
    vec![
        (1, 1, 1, 1, 1),
        (1, 1, 2, 5, 2),
        (2, 7, 3, 17, 3),
        (3, 16, 4, 32, 2),
        (3, 64, 8, 64, 4),
        (3, 101, 2, 96, 8),
        (4, 33, 5, 48, 4),
        (5, 12, 3, 40, 16),
        (2, 3, 1, 128, 4),
        (3, 655, 16, 115, 4),
    ]
}

#[test]
fn planned_and_sharded_cs_match_scalar_baseline_bitwise() {
    for (case, &(v, w, d, k, shards)) in grid().iter().enumerate() {
        let seed = 0xA11CE ^ case as u64;
        let mut rng = Rng::new(seed);
        let ids: Vec<u64> = (0..k).map(|_| rng.below(8 * w) as u64).collect();
        let deltas: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();

        let h = SketchHasher::new(v, w, seed);
        let mut truth = vec![0.0f32; v * w * d];
        scalar_cs_update(&mut truth, &h, d, &ids, &deltas);
        let mut truth_out = vec![0.0f32; k * d];
        scalar_cs_query(&truth, &h, d, &ids, &mut truth_out);

        for s in [1usize, shards] {
            let mut cs = CountSketch::new(v, w, d, seed).with_shards(s);
            let plan = cs.plan(&ids);
            cs.update_with(&plan, &deltas);
            assert_eq!(cs.tensor().data(), &truth[..], "update case {case} shards {s}");
            let mut out = vec![0.0f32; k * d];
            cs.query_with(&plan, &mut out);
            assert_eq!(out, truth_out, "query case {case} shards {s}");
        }
    }
}

#[test]
fn planned_and_sharded_cms_match_scalar_baseline_bitwise() {
    for (case, &(v, w, d, k, shards)) in grid().iter().enumerate() {
        let seed = 0xB0B ^ ((case as u64) << 3);
        let mut rng = Rng::new(seed);
        let ids: Vec<u64> = (0..k).map(|_| rng.below(8 * w) as u64).collect();
        // signed deltas on purpose: the paper feeds signed Adam-v deltas
        // into the CMS, and the equivalence must hold there too
        let deltas: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();

        let h = SketchHasher::new(v, w, seed);
        let mut truth = vec![0.0f32; v * w * d];
        scalar_cms_update(&mut truth, &h, d, &ids, &deltas);
        let mut truth_out = vec![0.0f32; k * d];
        scalar_cms_query(&truth, &h, d, &ids, &mut truth_out);

        for s in [1usize, shards] {
            let mut cms = CountMinSketch::new(v, w, d, seed).with_shards(s);
            let plan = cms.plan(&ids);
            cms.update_with(&plan, &deltas);
            assert_eq!(cms.tensor().data(), &truth[..], "update case {case} shards {s}");
            let mut out = vec![0.0f32; k * d];
            cms.query_with(&plan, &mut out);
            assert_eq!(out, truth_out, "query case {case} shards {s}");
        }
    }
}

/// Randomized sweep beyond the fixed grid: duplicate-heavy id batches,
/// repeated update/query rounds, random shard counts.
#[test]
fn randomized_plan_shard_equivalence_property() {
    check("plan-shard-equiv", 24, 0x5EED5, |rng| {
        let v = 1 + rng.below(5);
        let w = 1 + rng.below(96);
        let d = 1 + rng.below(9);
        let k = 1 + rng.below(80);
        let shards = 2 + rng.below(9);
        let seed = rng.next_u64();
        // duplicate-heavy: ids drawn from a small universe
        let ids: Vec<u64> = (0..k).map(|_| rng.below(1 + w / 2) as u64).collect();

        let h = SketchHasher::new(v, w, seed);
        let mut truth = vec![0.0f32; v * w * d];
        let mut seq = CountSketch::new(v, w, d, seed);
        let mut par = CountSketch::new(v, w, d, seed).with_shards(shards);
        let plan = SketchPlan::build(&h, &ids);
        for _round in 0..3 {
            let deltas: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            scalar_cs_update(&mut truth, &h, d, &ids, &deltas);
            seq.update_with(&plan, &deltas);
            par.update_with(&plan, &deltas);
            if seq.tensor().data() != &truth[..] {
                return Err("sequential planned update drifted from scalar".into());
            }
            if par.tensor().data() != &truth[..] {
                return Err(format!("sharded update drifted (shards={shards})"));
            }
            let mut truth_out = vec![0.0f32; k * d];
            scalar_cs_query(&truth, &h, d, &ids, &mut truth_out);
            let mut out = vec![0.0f32; k * d];
            par.query_with(&plan, &mut out);
            if out != truth_out {
                return Err(format!("sharded query drifted (shards={shards})"));
            }
        }
        Ok(())
    });
}

/// Golden guard on the Python/AOT interchange: `SketchPlan` must carry
/// exactly the `buckets_and_signs` tables (themselves pinned against
/// `python/compile/kernels/hashing.py` golden vectors).
#[test]
fn plan_tables_match_buckets_and_signs_golden() {
    // the pinned cross-language vectors
    let h = SketchHasher::new(2, 16, 7);
    let plan = SketchPlan::build(&h, &[0, 1, 2, 3]);
    assert_eq!(plan.idx(), &[4, 6, 5, 1, 6, 6, 0, 12]);
    assert_eq!(plan.signs(), &[-1.0, -1.0, 1.0, -1.0, -1.0, -1.0, -1.0, 1.0]);
    // and agreement with the batched hasher across random families
    let mut rng = Rng::new(99);
    for _ in 0..16 {
        let v = 1 + rng.below(6);
        let w = 1 + rng.below(512);
        let seed = rng.next_u64();
        let k = 1 + rng.below(64);
        let ids: Vec<u64> = (0..k).map(|_| rng.next_u64() % 100_000).collect();
        let h = SketchHasher::new(v, w, seed);
        let (idx, sign) = h.buckets_and_signs(&ids);
        let plan = SketchPlan::build(&h, &ids);
        assert_eq!(plan.idx(), &idx[..]);
        assert_eq!(plan.signs(), &sign[..]);
    }
}

/// End-to-end optimizer equivalence: a cs-adam step driven by one shared
/// plan (and optionally sharded) must reproduce the rehash-per-call
/// sequence exactly. The reference below performs the QUERY → Δ → UPDATE
/// → re-QUERY → apply sequence through the scalar baseline.
#[test]
fn cs_adam_step_matches_scalar_reference_bitwise() {
    use csopt::optim::{OptimSpec, RowShape};

    let (v, w, d, k, n) = (3usize, 257usize, 8usize, 48usize, 2048usize);
    let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
    let seed = 0x5EED; // default hash seed of the spec layer
    let shape = RowShape::new(n, d).with_sketch(v, w);

    let mut rng = Rng::new(17);
    let ids: Vec<u64> = rng.sample_distinct(n, k).into_iter().map(|x| x as u64).collect();

    // scalar reference state
    let h = SketchHasher::new(v, w, seed);
    let mut m_data = vec![0.0f32; v * w * d];
    let mut v_data = vec![0.0f32; v * w * d];
    let mut rows_ref = vec![0.5f32; k * d];

    // plan-based production optimizers (sequential + sharded)
    let mut opt_seq = OptimSpec::parse("cs-adam").unwrap().build_row(&shape, None).unwrap();
    let mut opt_par =
        OptimSpec::parse("cs-adam@shard=4").unwrap().build_row(&shape, None).unwrap();
    let mut rows_seq = rows_ref.clone();
    let mut rows_par = rows_ref.clone();

    let mut est_m = vec![0.0f32; k * d];
    let mut est_v = vec![0.0f32; k * d];
    let mut delta = vec![0.0f32; k * d];
    for t in 1..=5 {
        let grads: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();

        // reference step: m += (1−β1)(g − m̂); v += (1−β2)(g² − v̂)
        scalar_cs_query(&m_data, &h, d, &ids, &mut est_m);
        for i in 0..k * d {
            delta[i] = (1.0 - b1) * (grads[i] - est_m[i]);
        }
        scalar_cs_update(&mut m_data, &h, d, &ids, &delta);
        scalar_cs_query(&m_data, &h, d, &ids, &mut est_m);
        scalar_cms_query(&v_data, &h, d, &ids, &mut est_v);
        for i in 0..k * d {
            delta[i] = (1.0 - b2) * (grads[i] * grads[i] - est_v[i]);
        }
        scalar_cms_update(&mut v_data, &h, d, &ids, &delta);
        scalar_cms_query(&v_data, &h, d, &ids, &mut est_v);
        let bc1 = 1.0 - b1.powi(t as i32);
        let bc2 = 1.0 - b2.powi(t as i32);
        for i in 0..k * d {
            let m_hat = est_m[i] / bc1;
            let v_hat = est_v[i].max(0.0) / bc2;
            rows_ref[i] -= 1e-3 * m_hat / (v_hat.sqrt() + eps);
        }

        opt_seq.step_rows(&ids, &mut rows_seq, &grads, 1e-3, t);
        opt_par.step_rows(&ids, &mut rows_par, &grads, 1e-3, t);
        assert_eq!(rows_seq, rows_ref, "planned step drifted at t={t}");
        assert_eq!(rows_par, rows_ref, "sharded step drifted at t={t}");
    }
}
