//! Plan/shard equivalence suite (DESIGN.md §2/§5 invariants).
//!
//! The scalar baseline below is *re-implemented from `SketchHasher`
//! primitives* — it mirrors the pre-plan per-id loops — so these tests
//! keep guarding the refactored execution paths even though the id-based
//! sketch methods are now wrappers over the same plan core. Everything is
//! compared **bit-exactly** (`==` on f32 buffers), because hash-once plans
//! and sharding are pure execution-policy changes: they must not move a
//! single ulp.

use std::thread;

use csopt::comm::{mem_world, DistCtx};
use csopt::optim::{CmsAdagrad, CmsAdamV, CsAdam, CsMomentum, HybridAdamV, RowOptimizer};
use csopt::sketch::{CountMinSketch, CountSketch, SketchHasher, SketchPlan};
use csopt::util::proptest::check;
use csopt::util::rng::Rng;

/// Scalar count-sketch UPDATE exactly as the pre-plan implementation:
/// per depth, per item, hash and scatter-add the signed delta.
fn scalar_cs_update(data: &mut [f32], h: &SketchHasher, d: usize, ids: &[u64], deltas: &[f32]) {
    let w = h.width();
    for j in 0..h.depth() {
        for (t, &id) in ids.iter().enumerate() {
            let (b, s) = h.bucket_sign(j, id);
            let row = &mut data[(j * w + b) * d..(j * w + b + 1) * d];
            let delta = &deltas[t * d..(t + 1) * d];
            if s >= 0.0 {
                for (r, &x) in row.iter_mut().zip(delta) {
                    *r += x;
                }
            } else {
                for (r, &x) in row.iter_mut().zip(delta) {
                    *r -= x;
                }
            }
        }
    }
}

/// Scalar count-sketch QUERY: signed median over depth, per item.
fn scalar_cs_query(data: &[f32], h: &SketchHasher, d: usize, ids: &[u64], out: &mut [f32]) {
    let w = h.width();
    let v = h.depth();
    let mut vals = vec![0.0f32; v];
    for (t, &id) in ids.iter().enumerate() {
        for i in 0..d {
            for j in 0..v {
                let (b, s) = h.bucket_sign(j, id);
                vals[j] = s * data[(j * w + b) * d + i];
            }
            // median identical to the production kernels: sort + middle
            // (v ≤ 3 there is a min/max network computing the same value)
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            out[t * d + i] = if v % 2 == 1 {
                vals[v / 2]
            } else {
                0.5 * (vals[v / 2 - 1] + vals[v / 2])
            };
        }
    }
}

/// Scalar count-min UPDATE/QUERY (unsigned add, min over depth).
fn scalar_cms_update(data: &mut [f32], h: &SketchHasher, d: usize, ids: &[u64], deltas: &[f32]) {
    let w = h.width();
    for j in 0..h.depth() {
        for (t, &id) in ids.iter().enumerate() {
            let b = h.bucket(j, id);
            let row = &mut data[(j * w + b) * d..(j * w + b + 1) * d];
            for (r, &x) in row.iter_mut().zip(&deltas[t * d..(t + 1) * d]) {
                *r += x;
            }
        }
    }
}

fn scalar_cms_query(data: &[f32], h: &SketchHasher, d: usize, ids: &[u64], out: &mut [f32]) {
    let w = h.width();
    for (t, &id) in ids.iter().enumerate() {
        for i in 0..d {
            let mut m = f32::INFINITY;
            for j in 0..h.depth() {
                let b = h.bucket(j, id);
                let x = data[(j * w + b) * d + i];
                if x < m {
                    m = x;
                }
            }
            out[t * d + i] = m;
        }
    }
}

/// The (v, w, d, k, shards) grid of the issue's acceptance criterion,
/// mixing tiny degenerate geometries with paper-adjacent ones.
fn grid() -> Vec<(usize, usize, usize, usize, usize)> {
    vec![
        (1, 1, 1, 1, 1),
        (1, 1, 2, 5, 2),
        (2, 7, 3, 17, 3),
        (3, 16, 4, 32, 2),
        (3, 64, 8, 64, 4),
        (3, 101, 2, 96, 8),
        (4, 33, 5, 48, 4),
        (5, 12, 3, 40, 16),
        (2, 3, 1, 128, 4),
        (3, 655, 16, 115, 4),
        // k·d ≥ SERIAL_MIN_KD: large enough that sharded execution (and
        // the sharded fused phases, DESIGN.md §12) actually engages
        // instead of the small-batch serial fast path
        (3, 655, 8, 1152, 4),
    ]
}

#[test]
fn planned_and_sharded_cs_match_scalar_baseline_bitwise() {
    for (case, &(v, w, d, k, shards)) in grid().iter().enumerate() {
        let seed = 0xA11CE ^ case as u64;
        let mut rng = Rng::new(seed);
        let ids: Vec<u64> = (0..k).map(|_| rng.below(8 * w) as u64).collect();
        let deltas: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();

        let h = SketchHasher::new(v, w, seed);
        let mut truth = vec![0.0f32; v * w * d];
        scalar_cs_update(&mut truth, &h, d, &ids, &deltas);
        let mut truth_out = vec![0.0f32; k * d];
        scalar_cs_query(&truth, &h, d, &ids, &mut truth_out);

        for s in [1usize, shards] {
            let mut cs = CountSketch::new(v, w, d, seed).with_shards(s);
            let plan = cs.plan(&ids);
            cs.update_with(&plan, &deltas);
            assert_eq!(cs.tensor().data(), &truth[..], "update case {case} shards {s}");
            let mut out = vec![0.0f32; k * d];
            cs.query_with(&plan, &mut out);
            assert_eq!(out, truth_out, "query case {case} shards {s}");
        }
    }
}

#[test]
fn planned_and_sharded_cms_match_scalar_baseline_bitwise() {
    for (case, &(v, w, d, k, shards)) in grid().iter().enumerate() {
        let seed = 0xB0B ^ ((case as u64) << 3);
        let mut rng = Rng::new(seed);
        let ids: Vec<u64> = (0..k).map(|_| rng.below(8 * w) as u64).collect();
        // signed deltas on purpose: the paper feeds signed Adam-v deltas
        // into the CMS, and the equivalence must hold there too
        let deltas: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();

        let h = SketchHasher::new(v, w, seed);
        let mut truth = vec![0.0f32; v * w * d];
        scalar_cms_update(&mut truth, &h, d, &ids, &deltas);
        let mut truth_out = vec![0.0f32; k * d];
        scalar_cms_query(&truth, &h, d, &ids, &mut truth_out);

        for s in [1usize, shards] {
            let mut cms = CountMinSketch::new(v, w, d, seed).with_shards(s);
            let plan = cms.plan(&ids);
            cms.update_with(&plan, &deltas);
            assert_eq!(cms.tensor().data(), &truth[..], "update case {case} shards {s}");
            let mut out = vec![0.0f32; k * d];
            cms.query_with(&plan, &mut out);
            assert_eq!(out, truth_out, "query case {case} shards {s}");
        }
    }
}

/// Randomized sweep beyond the fixed grid: duplicate-heavy id batches,
/// repeated update/query rounds, random shard counts.
#[test]
fn randomized_plan_shard_equivalence_property() {
    check("plan-shard-equiv", 24, 0x5EED5, |rng| {
        let v = 1 + rng.below(5);
        let w = 1 + rng.below(96);
        let d = 1 + rng.below(9);
        let k = 1 + rng.below(80);
        let shards = 2 + rng.below(9);
        let seed = rng.next_u64();
        // duplicate-heavy: ids drawn from a small universe
        let ids: Vec<u64> = (0..k).map(|_| rng.below(1 + w / 2) as u64).collect();

        let h = SketchHasher::new(v, w, seed);
        let mut truth = vec![0.0f32; v * w * d];
        let mut seq = CountSketch::new(v, w, d, seed);
        let mut par = CountSketch::new(v, w, d, seed).with_shards(shards);
        let plan = SketchPlan::build(&h, &ids);
        for _round in 0..3 {
            let deltas: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            scalar_cs_update(&mut truth, &h, d, &ids, &deltas);
            seq.update_with(&plan, &deltas);
            par.update_with(&plan, &deltas);
            if seq.tensor().data() != &truth[..] {
                return Err("sequential planned update drifted from scalar".into());
            }
            if par.tensor().data() != &truth[..] {
                return Err(format!("sharded update drifted (shards={shards})"));
            }
            let mut truth_out = vec![0.0f32; k * d];
            scalar_cs_query(&truth, &h, d, &ids, &mut truth_out);
            let mut out = vec![0.0f32; k * d];
            par.query_with(&plan, &mut out);
            if out != truth_out {
                return Err(format!("sharded query drifted (shards={shards})"));
            }
        }
        Ok(())
    });
}

/// Golden guard on the Python/AOT interchange: `SketchPlan` must carry
/// exactly the `buckets_and_signs` tables (themselves pinned against
/// `python/compile/kernels/hashing.py` golden vectors).
#[test]
fn plan_tables_match_buckets_and_signs_golden() {
    // the pinned cross-language vectors
    let h = SketchHasher::new(2, 16, 7);
    let plan = SketchPlan::build(&h, &[0, 1, 2, 3]);
    assert_eq!(plan.idx(), &[4, 6, 5, 1, 6, 6, 0, 12]);
    assert_eq!(plan.signs(), &[-1.0, -1.0, 1.0, -1.0, -1.0, -1.0, -1.0, 1.0]);
    // and agreement with the batched hasher across random families
    let mut rng = Rng::new(99);
    for _ in 0..16 {
        let v = 1 + rng.below(6);
        let w = 1 + rng.below(512);
        let seed = rng.next_u64();
        let k = 1 + rng.below(64);
        let ids: Vec<u64> = (0..k).map(|_| rng.next_u64() % 100_000).collect();
        let h = SketchHasher::new(v, w, seed);
        let (idx, sign) = h.buckets_and_signs(&ids);
        let plan = SketchPlan::build(&h, &ids);
        assert_eq!(plan.idx(), &idx[..]);
        assert_eq!(plan.signs(), &sign[..]);
    }
}

/// End-to-end optimizer equivalence: a cs-adam step driven by one shared
/// plan (and optionally sharded) must reproduce the rehash-per-call
/// sequence exactly. The reference below performs the QUERY → Δ → UPDATE
/// → re-QUERY → apply sequence through the scalar baseline.
#[test]
fn cs_adam_step_matches_scalar_reference_bitwise() {
    use csopt::optim::{OptimSpec, RowShape};

    let (v, w, d, k, n) = (3usize, 257usize, 8usize, 48usize, 2048usize);
    let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
    let seed = 0x5EED; // default hash seed of the spec layer
    let shape = RowShape::new(n, d).with_sketch(v, w);

    let mut rng = Rng::new(17);
    let ids: Vec<u64> = rng.sample_distinct(n, k).into_iter().map(|x| x as u64).collect();

    // scalar reference state
    let h = SketchHasher::new(v, w, seed);
    let mut m_data = vec![0.0f32; v * w * d];
    let mut v_data = vec![0.0f32; v * w * d];
    let mut rows_ref = vec![0.5f32; k * d];

    // plan-based production optimizers (sequential + sharded)
    let mut opt_seq = OptimSpec::parse("cs-adam").unwrap().build_row(&shape, None).unwrap();
    let mut opt_par =
        OptimSpec::parse("cs-adam@shard=4").unwrap().build_row(&shape, None).unwrap();
    let mut rows_seq = rows_ref.clone();
    let mut rows_par = rows_ref.clone();

    let mut est_m = vec![0.0f32; k * d];
    let mut est_v = vec![0.0f32; k * d];
    let mut delta = vec![0.0f32; k * d];
    for t in 1..=5 {
        let grads: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();

        // reference step: m += (1−β1)(g − m̂); v += (1−β2)(g² − v̂)
        scalar_cs_query(&m_data, &h, d, &ids, &mut est_m);
        for i in 0..k * d {
            delta[i] = (1.0 - b1) * (grads[i] - est_m[i]);
        }
        scalar_cs_update(&mut m_data, &h, d, &ids, &delta);
        scalar_cs_query(&m_data, &h, d, &ids, &mut est_m);
        scalar_cms_query(&v_data, &h, d, &ids, &mut est_v);
        for i in 0..k * d {
            delta[i] = (1.0 - b2) * (grads[i] * grads[i] - est_v[i]);
        }
        scalar_cms_update(&mut v_data, &h, d, &ids, &delta);
        scalar_cms_query(&v_data, &h, d, &ids, &mut est_v);
        let bc1 = 1.0 - b1.powi(t as i32);
        let bc2 = 1.0 - b2.powi(t as i32);
        for i in 0..k * d {
            let m_hat = est_m[i] / bc1;
            let v_hat = est_v[i].max(0.0) / bc2;
            rows_ref[i] -= 1e-3 * m_hat / (v_hat.sqrt() + eps);
        }

        opt_seq.step_rows(&ids, &mut rows_seq, &grads, 1e-3, t);
        opt_par.step_rows(&ids, &mut rows_par, &grads, 1e-3, t);
        assert_eq!(rows_seq, rows_ref, "planned step drifted at t={t}");
        assert_eq!(rows_par, rows_ref, "sharded step drifted at t={t}");
    }
}

/// DESIGN.md §12 invariant at the sketch level: `step_fused` must be
/// bit-identical to the unfused QUERY → Δ → UPDATE → re-QUERY sequence it
/// replaces — returned estimates *and* tensor state — for both sketch
/// families, both `pre_query` modes, every shard count, and repeated
/// rounds over duplicate-heavy batches. The unfused twin runs sequential
/// (shards = 1), so this also re-proves fused sharding against the
/// already-pinned sequential semantics.
#[test]
fn fused_step_matches_unfused_sequence_bitwise() {
    for (case, &(v, w, d, k, shards)) in grid().iter().enumerate() {
        let seed = 0xF05ED ^ ((case as u64) << 4);
        let mut rng = Rng::new(seed);
        let kd = k * d;
        // duplicate-heavy: ids drawn from a small universe
        let ids: Vec<u64> = (0..k).map(|_| rng.below(1 + w / 2) as u64).collect();
        let rounds: Vec<Vec<f32>> =
            (0..3).map(|_| (0..kd).map(|_| rng.normal_f32(0.0, 1.0)).collect()).collect();

        for s in [1usize, 2, shards] {
            // count-sketch, pre-queried Δ = 0.5·m̂ + g (momentum-shaped)
            let mut fused = CountSketch::new(v, w, d, seed).with_shards(s);
            let mut plain = CountSketch::new(v, w, d, seed);
            let plan = fused.plan(&ids);
            let mut est_f = vec![0.0f32; kd];
            let mut est_p = vec![0.0f32; kd];
            let mut delta = vec![0.0f32; kd];
            for g in &rounds {
                let make = &mut |est: &[f32], out: &mut [f32]| {
                    for i in 0..kd {
                        out[i] = 0.5 * est[i] + g[i];
                    }
                };
                fused.step_fused(&plan, true, make, &mut est_f);
                plain.query_with(&plan, &mut est_p);
                for i in 0..kd {
                    delta[i] = 0.5 * est_p[i] + g[i];
                }
                plain.update_with(&plan, &delta);
                plain.query_with(&plan, &mut est_p);
                assert_eq!(est_f, est_p, "cs est, case {case} shards {s}");
            }
            assert_eq!(
                fused.tensor().data(),
                plain.tensor().data(),
                "cs tensor, case {case} shards {s}"
            );

            // count-min, both pre-query modes: Δ = g² − 0.001·v̂
            // (adam-v-shaped) and the estimate-free Δ = g² (adagrad-shaped)
            for pre in [true, false] {
                let mut fused = CountMinSketch::new(v, w, d, seed).with_shards(s);
                let mut plain = CountMinSketch::new(v, w, d, seed);
                for g in &rounds {
                    let make = &mut |est: &[f32], out: &mut [f32]| {
                        for i in 0..kd {
                            out[i] =
                                if pre { g[i] * g[i] - 0.001 * est[i] } else { g[i] * g[i] };
                        }
                    };
                    fused.step_fused(&plan, pre, make, &mut est_f);
                    if pre {
                        plain.query_with(&plan, &mut est_p);
                    }
                    for i in 0..kd {
                        delta[i] =
                            if pre { g[i] * g[i] - 0.001 * est_p[i] } else { g[i] * g[i] };
                    }
                    plain.update_with(&plan, &delta);
                    plain.query_with(&plan, &mut est_p);
                    assert_eq!(est_f, est_p, "cms est, case {case} shards {s} pre {pre}");
                }
                assert_eq!(
                    fused.tensor().data(),
                    plain.tensor().data(),
                    "cms tensor, case {case} shards {s} pre {pre}"
                );
            }
        }
    }
}

/// The acceptance criterion at the optimizer level: every sketched
/// optimizer's fused `step_rows` must reproduce the pre-fusion unfused
/// sequence (QUERY → Δ → UPDATE → re-QUERY → apply, driven here through
/// the plain `query_with`/`update_with` primitives) bit-exactly, at every
/// shard count, on duplicate-heavy batches.
#[test]
fn fused_optimizers_match_unfused_references_bitwise() {
    type RefStep = Box<dyn FnMut(&[u64], &mut [f32], &[f32], f32, usize)>;
    let (v, w, d, n, k) = (3usize, 53usize, 4usize, 96usize, 24usize);
    let (gm, b1, b2, eps) = (0.9f32, 0.9f32, 0.999f32, 1e-8f32);
    let seed = 11u64;

    for shards in [1usize, 2, 4] {
        let mut pairs: Vec<(Box<dyn RowOptimizer>, RefStep)> = Vec::new();

        // cs-momentum: m += (γ−1)·m̂ + g; x ← x − η·m
        let mut sk = CountSketch::new(v, w, d, seed);
        pairs.push((
            Box::new(CsMomentum::new(v, w, d, seed, gm).with_shards(shards)),
            Box::new(move |ids: &[u64], rows: &mut [f32], grads: &[f32], lr: f32, _t| {
                let kd = ids.len() * d;
                let plan = sk.plan(ids);
                let (mut est, mut delta) = (vec![0.0f32; kd], vec![0.0f32; kd]);
                sk.query_with(&plan, &mut est);
                for i in 0..kd {
                    delta[i] = (gm - 1.0) * est[i] + grads[i];
                }
                sk.update_with(&plan, &delta);
                sk.query_with(&plan, &mut est);
                for i in 0..kd {
                    rows[i] -= lr * est[i];
                }
            }),
        ));

        // cms-adagrad: acc += g²; x ← x − η·g/(√acc + ε)
        let mut sk = CountMinSketch::new(v, w, d, seed);
        pairs.push((
            Box::new(CmsAdagrad::new(v, w, d, seed, eps).with_shards(shards)),
            Box::new(move |ids: &[u64], rows: &mut [f32], grads: &[f32], lr: f32, _t| {
                let kd = ids.len() * d;
                let plan = sk.plan(ids);
                let (mut est, mut delta) = (vec![0.0f32; kd], vec![0.0f32; kd]);
                for i in 0..kd {
                    delta[i] = grads[i] * grads[i];
                }
                sk.update_with(&plan, &delta);
                sk.query_with(&plan, &mut est);
                for i in 0..kd {
                    rows[i] -= lr * grads[i] / (est[i].max(0.0).sqrt() + eps);
                }
            }),
        ));

        // cs-adam: CS m / CMS v under one shared plan
        let mut sk_m = CountSketch::new(v, w, d, seed);
        let mut sk_v = CountMinSketch::new(v, w, d, seed);
        pairs.push((
            Box::new(CsAdam::new(v, w, d, seed, b1, b2, eps).with_shards(shards)),
            Box::new(move |ids: &[u64], rows: &mut [f32], grads: &[f32], lr: f32, t| {
                let kd = ids.len() * d;
                let plan = sk_m.plan(ids);
                let (mut est_m, mut est_v) = (vec![0.0f32; kd], vec![0.0f32; kd]);
                let mut delta = vec![0.0f32; kd];
                sk_m.query_with(&plan, &mut est_m);
                for i in 0..kd {
                    delta[i] = (1.0 - b1) * (grads[i] - est_m[i]);
                }
                sk_m.update_with(&plan, &delta);
                sk_m.query_with(&plan, &mut est_m);
                sk_v.query_with(&plan, &mut est_v);
                for i in 0..kd {
                    delta[i] = (1.0 - b2) * (grads[i] * grads[i] - est_v[i]);
                }
                sk_v.update_with(&plan, &delta);
                sk_v.query_with(&plan, &mut est_v);
                let bc1 = 1.0 - b1.powi(t as i32);
                let bc2 = 1.0 - b2.powi(t as i32);
                for i in 0..kd {
                    let m_hat = est_m[i] / bc1;
                    let v_hat = est_v[i].max(0.0) / bc2;
                    rows[i] -= lr * m_hat / (v_hat.sqrt() + eps);
                }
            }),
        ));

        // cms-adam-v: CMS v only
        let mut sk_v = CountMinSketch::new(v, w, d, seed);
        pairs.push((
            Box::new(CmsAdamV::new(v, w, d, seed, b2, eps).with_shards(shards)),
            Box::new(move |ids: &[u64], rows: &mut [f32], grads: &[f32], lr: f32, t| {
                let kd = ids.len() * d;
                let plan = sk_v.plan(ids);
                let (mut est_v, mut delta) = (vec![0.0f32; kd], vec![0.0f32; kd]);
                sk_v.query_with(&plan, &mut est_v);
                for i in 0..kd {
                    delta[i] = (1.0 - b2) * (grads[i] * grads[i] - est_v[i]);
                }
                sk_v.update_with(&plan, &delta);
                sk_v.query_with(&plan, &mut est_v);
                let bc2 = 1.0 - b2.powi(t as i32);
                for i in 0..kd {
                    let v_hat = est_v[i].max(0.0) / bc2;
                    rows[i] -= lr * grads[i] / (v_hat.sqrt() + eps);
                }
            }),
        ));

        // hybrid adam-v: dense m, CMS v
        let mut m_dense = vec![0.0f32; n * d];
        let mut sk_v = CountMinSketch::new(v, w, d, seed);
        pairs.push((
            Box::new(HybridAdamV::new(n, v, w, d, seed, b1, b2, eps).with_shards(shards)),
            Box::new(move |ids: &[u64], rows: &mut [f32], grads: &[f32], lr: f32, t| {
                let kd = ids.len() * d;
                let plan = sk_v.plan(ids);
                let (mut est_v, mut delta) = (vec![0.0f32; kd], vec![0.0f32; kd]);
                sk_v.query_with(&plan, &mut est_v);
                for i in 0..kd {
                    delta[i] = (1.0 - b2) * (grads[i] * grads[i] - est_v[i]);
                }
                sk_v.update_with(&plan, &delta);
                sk_v.query_with(&plan, &mut est_v);
                let bc1 = 1.0 - b1.powi(t as i32);
                let bc2 = 1.0 - b2.powi(t as i32);
                for (ti, &id) in ids.iter().enumerate() {
                    let m = &mut m_dense[id as usize * d..(id as usize + 1) * d];
                    for i in 0..d {
                        let gi = grads[ti * d + i];
                        m[i] = b1 * m[i] + (1.0 - b1) * gi;
                        let m_hat = m[i] / bc1;
                        let v_hat = est_v[ti * d + i].max(0.0) / bc2;
                        rows[ti * d + i] -= lr * m_hat / (v_hat.sqrt() + eps);
                    }
                }
            }),
        ));

        for (mut fused, mut reference) in pairs {
            let name = fused.name();
            let mut rng = Rng::new(0xAB ^ shards as u64);
            let mut rows_f = vec![0.25f32; k * d];
            let mut rows_r = rows_f.clone();
            for t in 1..=5 {
                // duplicate-heavy batches (small id universe)
                let ids: Vec<u64> = (0..k).map(|_| rng.below(n) as u64).collect();
                let g: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                fused.step_rows(&ids, &mut rows_f, &g, 1e-2, t);
                reference(&ids, &mut rows_r, &g, 1e-2, t);
                assert_eq!(rows_f, rows_r, "{name} shards={shards} t={t}");
            }
        }
    }
}

/// The PartitionedStore leg of the §12 invariant: on a width-partitioned
/// store `step_fused` falls back to the unfused sequence (the QUERY
/// all-reduce is a fusion barrier), and every rank of a 2-rank
/// mem-transport world must still match the fused local path bit-exactly.
#[test]
fn partitioned_fused_fallback_matches_local_bitwise() {
    let (v, w, d, n, k) = (3usize, 48usize, 4usize, 96usize, 16usize);
    let world = 2usize;

    // shared trajectory (duplicate-heavy batches)
    let mut rng = Rng::new(0xD157);
    let traj: Vec<(Vec<u64>, Vec<f32>)> = (0..4)
        .map(|_| {
            let ids: Vec<u64> = (0..k).map(|_| rng.below(n) as u64).collect();
            let grads: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            (ids, grads)
        })
        .collect();

    // fused local baselines: one pre-query optimizer, one query-free
    let run_local = |mut opts: Vec<Box<dyn RowOptimizer>>| -> Vec<Vec<f32>> {
        let mut rows = vec![vec![0.5f32; k * d]; opts.len()];
        for (t, (ids, grads)) in traj.iter().enumerate() {
            for (o, r) in opts.iter_mut().zip(rows.iter_mut()) {
                o.step_rows(ids, r, grads, 1e-2, t + 1);
            }
        }
        rows
    };
    let rows_local = run_local(vec![
        Box::new(CsAdam::new(v, w, d, 7, 0.9, 0.999, 1e-8)),
        Box::new(CmsAdagrad::new(v, w, d, 7, 1e-10)),
    ]);

    let outs: Vec<Vec<Vec<f32>>> = thread::scope(|s| {
        let handles: Vec<_> = mem_world(world)
            .into_iter()
            .enumerate()
            .map(|(rank, ep)| {
                let traj = &traj;
                s.spawn(move || {
                    let ctx = DistCtx::new(rank, world, ep);
                    let mut opts: Vec<Box<dyn RowOptimizer>> = vec![
                        Box::new(CsAdam::new(v, w, d, 7, 0.9, 0.999, 1e-8).with_store(&ctx)),
                        Box::new(CmsAdagrad::new(v, w, d, 7, 1e-10).with_store(&ctx)),
                    ];
                    let mut rows = vec![vec![0.5f32; k * d]; opts.len()];
                    for (t, (ids, grads)) in traj.iter().enumerate() {
                        for (o, r) in opts.iter_mut().zip(rows.iter_mut()) {
                            o.step_rows(ids, r, grads, 1e-2, t + 1);
                        }
                    }
                    rows
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (rank, rows) in outs.iter().enumerate() {
        for (oi, r) in rows.iter().enumerate() {
            assert_eq!(r, &rows_local[oi], "optimizer {oi} diverged on rank {rank}");
        }
    }
}
