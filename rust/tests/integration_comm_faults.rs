//! Fault-injection suite for the socket transports (`comm/uds.rs` +
//! `comm/tcp.rs`). A distributed run's failure mode must be a
//! contextual `Err` **within the I/O timeout** — never a hang: every
//! scenario drives a real transport endpoint against a deliberately
//! misbehaving raw-socket peer (`tests/common::rogue`) and every test
//! body runs under a `with_deadline` watchdog, so a regression back to
//! blocking forever fails in seconds.
//!
//! Both transports share the frame codec (`comm/frame.rs`), so the
//! rogue scenarios are parameterized over the wire: each fault runs
//! once per socket family and must surface the *same* error text —
//! the serve loop's recovery logic keys off these messages regardless
//! of transport.
#![cfg(unix)]

mod common;

use std::net::TcpListener;
use std::thread;
use std::time::Duration;

use csopt::comm::{TcpTransport, Transport, UdsTransport};

use common::{rogue, with_deadline};

/// Socket-level I/O timeout for the faulty scenarios: long enough for
/// loopback round-trips, short enough that timeout-path tests are fast.
const IO: Duration = Duration::from_millis(800);
/// Watchdog budget per test body — generous, but finite.
const DEADLINE: Duration = Duration::from_secs(30);

fn sock_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("csopt-fault-{tag}-{}.sock", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

#[derive(Clone, Copy, Debug)]
enum Wire {
    Uds,
    Tcp,
}

/// A coordinator endpoint of either family. TCP binds eagerly (port 0 →
/// the OS picks; rogue peers get the resolved address); UDS binds
/// inside `accept` and the rogue's connect retry covers the gap.
struct Coord {
    ep: String,
    tcp: Option<TcpListener>,
}

impl Coord {
    fn bind(wire: Wire, tag: &str) -> Coord {
        match wire {
            Wire::Uds => Coord { ep: sock_path(tag), tcp: None },
            Wire::Tcp => {
                let l = TcpListener::bind("127.0.0.1:0").unwrap();
                let ep = l.local_addr().unwrap().to_string();
                Coord { ep, tcp: Some(l) }
            }
        }
    }

    /// Rank 0's side: wait for `world - 1` hellos under the short
    /// timeout, behind the shared `Transport` face.
    fn accept(&self, world: usize) -> csopt::Result<Box<dyn Transport>> {
        match &self.tcp {
            None => UdsTransport::listen_with_timeout(&self.ep, world, IO)
                .map(|t| Box::new(t) as Box<dyn Transport>),
            Some(l) => TcpTransport::accept_world(l, &self.ep, world, IO)
                .map(|t| Box::new(t) as Box<dyn Transport>),
        }
    }

    fn cleanup(&self) {
        if self.tcp.is_none() {
            UdsTransport::cleanup(&self.ep);
        }
    }
}

/// Run one rogue-peer scenario: `fault` drives the misbehaving side
/// against the coordinator's 2-rank accept + allreduce, and the
/// coordinator's error text is returned for the per-wire assertion.
fn rogue_scenario(
    wire: Wire,
    tag: &str,
    fault: impl FnOnce(&mut rogue::Conn) + Send + 'static,
) -> String {
    let coord = Coord::bind(wire, tag);
    with_deadline(DEADLINE, move || {
        let ep = coord.ep.clone();
        let peer = thread::spawn(move || {
            let mut s = rogue::connect(&ep, DEADLINE);
            rogue::send_hello(&mut s, 1, 2);
            fault(&mut s);
            s // keep the stream alive until the coordinator has failed
        });
        let mut t0 = coord.accept(2).unwrap();
        let mut buf = vec![0.0f32; 4];
        let e = t0.all_reduce_sum(&mut buf).unwrap_err();
        drop(peer.join().unwrap());
        coord.cleanup();
        format!("{e:#}")
    })
}

/// Nobody ever connects: the coordinator's handshake must time out with
/// an actionable error instead of waiting forever.
fn handshake_timeout(wire: Wire) {
    let coord = Coord::bind(wire, "hstimeout");
    let err = with_deadline(DEADLINE, move || {
        let e = coord.accept(2).map(|_| ()).unwrap_err();
        coord.cleanup();
        format!("{e:#}")
    });
    assert!(err.contains("timed out waiting for workers"), "[{wire:?}] {err}");
}

#[test]
fn handshake_timeout_surfaces_err_uds() {
    handshake_timeout(Wire::Uds);
}

#[test]
fn handshake_timeout_surfaces_err_tcp() {
    handshake_timeout(Wire::Tcp);
}

/// The coordinator never appears: a worker's connect must give up with
/// the endpoint in the error. (The TCP leg binds a port and drops it, so
/// connects are refused rather than swallowed.)
fn connect_timeout(wire: Wire) {
    let ep = match wire {
        Wire::Uds => sock_path("cntimeout"),
        Wire::Tcp => {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
            // listener drops here — the port refuses from now on
        }
    };
    let err = with_deadline(DEADLINE, move || {
        let e = match wire {
            Wire::Uds => {
                UdsTransport::connect_with_timeout(&ep, 1, 2, IO).map(|_| ()).unwrap_err()
            }
            Wire::Tcp => {
                TcpTransport::connect_with_timeout(&ep, 1, 2, IO).map(|_| ()).unwrap_err()
            }
        };
        format!("{e:#}")
    });
    assert!(err.contains("never came up"), "[{wire:?}] {err}");
}

#[test]
fn connect_timeout_surfaces_err_uds() {
    connect_timeout(Wire::Uds);
}

#[test]
fn connect_timeout_surfaces_err_tcp() {
    connect_timeout(Wire::Tcp);
}

/// A peer that promises a 64-byte frame header but ships 10 bytes and
/// goes silent: the coordinator's collective read must fail within the
/// I/O timeout, naming the rank and the op it was receiving.
fn truncated_frame(wire: Wire) {
    let err = rogue_scenario(wire, "trunc", |s| {
        rogue::send_truncated_header(s, 64, 10);
    });
    assert!(err.contains("receiving allreduce partial from rank 1"), "[{wire:?}] {err}");
}

#[test]
fn truncated_frame_surfaces_err_uds() {
    truncated_frame(Wire::Uds);
}

#[test]
fn truncated_frame_surfaces_err_tcp() {
    truncated_frame(Wire::Tcp);
}

/// A header whose `n` promises vastly more payload f32s than the
/// collective's buffer holds: rejected as divergence before any giant
/// allocation or read.
fn oversized_payload_header(wire: Wire) {
    let err = rogue_scenario(wire, "oversize", |s| {
        rogue::send_frame(s, "{\"op\":\"allreduce\",\"n\":1000000}", &[]);
    });
    assert!(err.contains("exceeds the expected 4"), "[{wire:?}] {err}");
}

#[test]
fn oversized_payload_header_surfaces_err_uds() {
    oversized_payload_header(Wire::Uds);
}

#[test]
fn oversized_payload_header_surfaces_err_tcp() {
    oversized_payload_header(Wire::Tcp);
}

/// An implausible header *length* prefix (10 MB of JSON) is rejected
/// outright — a corrupt or hostile length cannot drive the allocation.
fn implausible_header_length(wire: Wire) {
    let err = rogue_scenario(wire, "hugehdr", |s| {
        rogue::send_truncated_header(s, 10_000_000, 16);
    });
    assert!(err.contains("implausible frame header length"), "[{wire:?}] {err}");
}

#[test]
fn implausible_header_length_surfaces_err_uds() {
    implausible_header_length(Wire::Uds);
}

#[test]
fn implausible_header_length_surfaces_err_tcp() {
    implausible_header_length(Wire::Tcp);
}

/// A worker that vanishes mid-collective (hello, then hangup): the
/// coordinator's all-reduce must surface the broken stream as an error,
/// not wedge the surviving ranks. This is the exact fault the serve
/// loop turns into a stall-and-resume restart (DESIGN.md §13).
fn worker_disconnect_mid_allreduce(wire: Wire) {
    let coord = Coord::bind(wire, "wdrop");
    let err = with_deadline(DEADLINE, move || {
        let ep = coord.ep.clone();
        let peer = thread::spawn(move || {
            let mut s = rogue::connect(&ep, DEADLINE);
            rogue::send_hello(&mut s, 1, 2);
            // dropping the stream closes it: the coordinator sees EOF
        });
        let mut t0 = coord.accept(2).unwrap();
        peer.join().unwrap();
        let mut buf = vec![0.0f32; 4];
        let e = t0.all_reduce_sum(&mut buf).unwrap_err();
        coord.cleanup();
        format!("{e:#}")
    });
    assert!(err.contains("receiving allreduce partial from rank 1"), "[{wire:?}] {err}");
}

#[test]
fn worker_disconnect_mid_allreduce_surfaces_err_uds() {
    worker_disconnect_mid_allreduce(Wire::Uds);
}

#[test]
fn worker_disconnect_mid_allreduce_surfaces_err_tcp() {
    worker_disconnect_mid_allreduce(Wire::Tcp);
}

/// A peer whose op sequence diverges from the coordinator's (it answers
/// the allreduce with a barrier frame) is called out as divergence.
fn diverged_op_sequence(wire: Wire) {
    let err = rogue_scenario(wire, "diverge", |s| {
        rogue::send_frame(s, "{\"op\":\"barrier\",\"n\":0}", &[]);
    });
    assert!(err.contains("diverged"), "[{wire:?}] {err}");
}

#[test]
fn diverged_op_sequence_surfaces_err_uds() {
    diverged_op_sequence(Wire::Uds);
}

#[test]
fn diverged_op_sequence_surfaces_err_tcp() {
    diverged_op_sequence(Wire::Tcp);
}

// ---------------------------------------------------------------------------
// Owned-rows codec faults (DESIGN.md §14): the sparse collective's
// defensive bounds, exercised over *both* real wires. The codec itself
// has Cursor-level unit tests in `comm/frame.rs`; these legs prove the
// same rejections fire through a live socket — err, never hang — and
// carry the rank/op context the serve loop keys off.
// ---------------------------------------------------------------------------

/// A rows-frame header for the rogue: the coordinator below always runs
/// `all_gather_rows` with `d = 2`, `id_space = 16`.
fn rows_header(n: usize, rows: usize, d: usize, total: usize) -> String {
    format!("{{\"op\":\"gatherrows\",\"n\":{n},\"rows\":{rows},\"d\":{d},\"total\":{total}}}")
}

/// Like [`rogue_scenario`], but the coordinator runs the sparse
/// collective: it contributes one owned row and waits for rank 1's
/// owned-rows frame — which `fault` supplies, malformed.
fn rogue_rows_scenario(
    wire: Wire,
    tag: &str,
    fault: impl FnOnce(&mut rogue::Conn) + Send + 'static,
) -> String {
    let coord = Coord::bind(wire, tag);
    with_deadline(DEADLINE, move || {
        let ep = coord.ep.clone();
        let peer = thread::spawn(move || {
            let mut s = rogue::connect(&ep, DEADLINE);
            rogue::send_hello(&mut s, 1, 2);
            fault(&mut s);
            s // keep the stream alive until the coordinator has failed
        });
        let mut t0 = coord.accept(2).unwrap();
        let (mut out_ids, mut out_rows) = (Vec::new(), Vec::new());
        let e = t0
            .all_gather_rows(&[0u64], &[1.0, 2.0], 2, 16, &mut out_ids, &mut out_rows)
            .unwrap_err();
        drop(peer.join().unwrap());
        coord.cleanup();
        format!("{e:#}")
    })
}

/// Duplicate (hence non-ascending) row ids: rejected by the reader's
/// independent re-validation, with the offending rank in the context.
fn rows_duplicate_ids(wire: Wire) {
    let err = rogue_rows_scenario(wire, "rowsdup", |s| {
        rogue::send_rows_frame(s, &rows_header(4, 2, 2, 16), &[5, 5], &[0.0; 4]);
    });
    assert!(err.contains("receiving gatherrows rows from rank 1"), "[{wire:?}] {err}");
    assert!(err.contains("strictly ascending"), "[{wire:?}] {err}");
}

#[test]
fn rows_duplicate_ids_surface_err_uds() {
    rows_duplicate_ids(Wire::Uds);
}

#[test]
fn rows_duplicate_ids_surface_err_tcp() {
    rows_duplicate_ids(Wire::Tcp);
}

/// A row id beyond the collective's id space: rejected before it could
/// drive an out-of-bounds reconstruction on any rank.
fn rows_out_of_range_id(wire: Wire) {
    let err = rogue_rows_scenario(wire, "rowsoob", |s| {
        rogue::send_rows_frame(s, &rows_header(2, 1, 2, 16), &[99], &[0.0; 2]);
    });
    assert!(err.contains("outside the id space"), "[{wire:?}] {err}");
}

#[test]
fn rows_out_of_range_id_surfaces_err_uds() {
    rows_out_of_range_id(Wire::Uds);
}

#[test]
fn rows_out_of_range_id_surfaces_err_tcp() {
    rows_out_of_range_id(Wire::Tcp);
}

/// A peer running different geometry (`d = 3` against the coordinator's
/// `d = 2`): called out as op-sequence divergence, not merged.
fn rows_geometry_mismatch(wire: Wire) {
    let err = rogue_rows_scenario(wire, "rowsgeom", |s| {
        rogue::send_rows_frame(s, &rows_header(3, 1, 3, 16), &[1], &[0.0; 3]);
    });
    assert!(err.contains("op sequences diverged"), "[{wire:?}] {err}");
}

#[test]
fn rows_geometry_mismatch_surfaces_err_uds() {
    rows_geometry_mismatch(Wire::Uds);
}

#[test]
fn rows_geometry_mismatch_surfaces_err_tcp() {
    rows_geometry_mismatch(Wire::Tcp);
}

/// A header claiming vastly more rows than the id space allows: bounded
/// before the id-list allocation, like the dense oversize fault.
fn rows_count_flood(wire: Wire) {
    let err = rogue_rows_scenario(wire, "rowsflood", |s| {
        rogue::send_rows_frame(s, &rows_header(2_000_000, 1_000_000, 2, 16), &[], &[]);
    });
    assert!(err.contains("more than the expected 16"), "[{wire:?}] {err}");
}

#[test]
fn rows_count_flood_surfaces_err_uds() {
    rows_count_flood(Wire::Uds);
}

#[test]
fn rows_count_flood_surfaces_err_tcp() {
    rows_count_flood(Wire::Tcp);
}

/// A rows frame that stops mid-id-list and goes silent: the coordinator
/// must fail within the I/O timeout — err, not hang — naming what it
/// was reading.
fn rows_truncated_frame(wire: Wire) {
    let err = rogue_rows_scenario(wire, "rowstrunc", |s| {
        // header promises 3 rows (24 id bytes + 24 payload bytes); ship
        // one id and nothing else
        rogue::send_rows_frame(s, &rows_header(6, 3, 2, 16), &[1], &[]);
    });
    assert!(err.contains("receiving gatherrows rows from rank 1"), "[{wire:?}] {err}");
    assert!(err.contains("reading owned-rows frame ids"), "[{wire:?}] {err}");
}

#[test]
fn rows_truncated_frame_surfaces_err_uds() {
    rows_truncated_frame(Wire::Uds);
}

#[test]
fn rows_truncated_frame_surfaces_err_tcp() {
    rows_truncated_frame(Wire::Tcp);
}

/// Honest-peer leg for the sparse collectives: a real 2-rank world over
/// each wire drives reduce-scatter + all-gather + the rows union under
/// the same short timeout, and the results — including denormal and
/// signed-zero payload bits — come back exact. The fault tests above
/// fail because of the injected faults, not because the sparse ops are
/// broken or the timeout unrealistic.
fn drive_sparse_rank(t: &mut dyn Transport, rank: usize) -> (Vec<f32>, Vec<f32>, Vec<u64>, Vec<f32>) {
    // reduce-scatter: 4 f32s, granule 2 → rank r owns [2r, 2r+2)
    let mut rs = vec![rank as f32 + 1.0; 4];
    t.reduce_scatter_sum(&mut rs, 2).unwrap();
    // all-gather: rank r publishes 10·(r+1) in its span; the NaNs
    // outside it must be overwritten, never shipped into the result
    let mut ag = vec![f32::NAN; 4];
    ag[rank * 2..rank * 2 + 2].fill(10.0 * (rank as f32 + 1.0));
    t.all_gather(&mut ag, 2).unwrap();
    // rows union: disjoint ids, bit-sensitive payloads
    let ids = vec![2 * rank as u64 + 1];
    let rows = vec![if rank == 0 { -0.0 } else { 3.25e-40 }, rank as f32];
    let (mut out_ids, mut out_rows) = (Vec::new(), Vec::new());
    t.all_gather_rows(&ids, &rows, 2, 8, &mut out_ids, &mut out_rows).unwrap();
    (rs, ag, out_ids, out_rows)
}

fn sparse_collectives_roundtrip(wire: Wire) {
    let coord = Coord::bind(wire, "sparseok");
    let (r0, r1) = with_deadline(DEADLINE, move || {
        let ep = coord.ep.clone();
        let worker = thread::spawn(move || {
            let mut t: Box<dyn Transport> = if ep.contains(':') {
                Box::new(TcpTransport::connect_with_timeout(&ep, 1, 2, IO).unwrap())
            } else {
                Box::new(UdsTransport::connect_with_timeout(&ep, 1, 2, IO).unwrap())
            };
            drive_sparse_rank(&mut *t, 1)
        });
        let mut t0 = coord.accept(2).unwrap();
        let r0 = drive_sparse_rank(&mut *t0, 0);
        let r1 = worker.join().unwrap();
        coord.cleanup();
        (r0, r1)
    });
    // each rank's owned reduce-scatter span holds the rank-order sum
    assert_eq!(r0.0[0..2], [3.0, 3.0], "[{wire:?}]");
    assert_eq!(r1.0[2..4], [3.0, 3.0], "[{wire:?}]");
    for (tag, r) in [("rank0", &r0), ("rank1", &r1)] {
        assert_eq!(r.1, vec![10.0, 10.0, 20.0, 20.0], "[{wire:?}] {tag} all_gather");
        assert_eq!(r.2, vec![1u64, 3], "[{wire:?}] {tag} union ids");
        let bits: Vec<u32> = r.3.iter().map(|x| x.to_bits()).collect();
        let want = [(-0.0f32).to_bits(), 0.0f32.to_bits(), 3.25e-40f32.to_bits(), 1.0f32.to_bits()];
        assert_eq!(bits, want, "[{wire:?}] {tag} union payload bits");
    }
}

#[test]
fn sparse_collectives_roundtrip_uds() {
    sparse_collectives_roundtrip(Wire::Uds);
}

#[test]
fn sparse_collectives_roundtrip_tcp() {
    sparse_collectives_roundtrip(Wire::Tcp);
}

/// The coordinator dies mid-collective: the *worker* side must error
/// within the timeout too (it is waiting for the reduced result).
#[test]
fn coordinator_disconnect_mid_allreduce_surfaces_err() {
    let path = sock_path("cdrop");
    let err = with_deadline(DEADLINE, move || {
        use std::io::Read;
        use std::os::unix::net::UnixListener;
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path).unwrap();
        let p2 = path.clone();
        let worker = thread::spawn(move || {
            let mut t = UdsTransport::connect_with_timeout(&p2, 1, 2, IO)
                .expect("handshake should complete before the fault");
            let mut buf = vec![1.0f32; 4];
            format!("{:#}", t.all_reduce_sum(&mut buf).unwrap_err())
        });
        // accept the worker, consume its hello frame, then hang up
        let (mut stream, _) = listener.accept().unwrap();
        stream.set_read_timeout(Some(DEADLINE)).unwrap();
        let mut len4 = [0u8; 4];
        stream.read_exact(&mut len4).unwrap();
        let mut hello = vec![0u8; u32::from_le_bytes(len4) as usize];
        stream.read_exact(&mut hello).unwrap();
        drop(stream);
        drop(listener);
        let e = worker.join().unwrap();
        let _ = std::fs::remove_file(&path);
        e
    });
    // the worker fails on the partial write (broken pipe) or on reading
    // the result (EOF/timeout) depending on kernel buffering — either
    // way it is a contextual rank-1 allreduce error, not a hang
    assert!(err.contains("rank 1") && err.contains("allreduce"), "{err}");
}

/// Sanity leg: with a *well-behaved* peer the short-timeout transport
/// still completes collectives — the fault tests above fail because of
/// the injected faults, not because the timeout is unrealistically low.
/// (The TCP equivalent lives in `comm/tcp.rs`'s unit tests.)
#[test]
fn short_timeout_still_completes_honest_collectives() {
    let path = sock_path("honest");
    with_deadline(DEADLINE, move || {
        let p2 = path.clone();
        let worker = thread::spawn(move || {
            let mut t = UdsTransport::connect_with_timeout(&p2, 1, 2, IO).unwrap();
            let mut buf = vec![2.0f32; 3];
            t.all_reduce_sum(&mut buf).unwrap();
            t.barrier().unwrap();
            buf
        });
        let mut t0 = UdsTransport::listen_with_timeout(&path, 2, IO).unwrap();
        let mut buf = vec![1.0f32; 3];
        t0.all_reduce_sum(&mut buf).unwrap();
        t0.barrier().unwrap();
        let wbuf = worker.join().unwrap();
        UdsTransport::cleanup(&path);
        assert_eq!(buf, vec![3.0f32; 3]);
        assert_eq!(wbuf, vec![3.0f32; 3]);
    });
}

/// A stale socket file from a crashed coordinator must not block a
/// restart (remove-then-bind with a liveness probe), while a *live*
/// coordinator on the same path is refused instead of hijacked.
#[test]
fn stale_socket_cleanup_vs_live_coordinator() {
    let path = sock_path("stale");
    with_deadline(DEADLINE, move || {
        // a dead coordinator's leftover: bind and drop, keeping the file
        {
            use std::os::unix::net::UnixListener;
            let _ = std::fs::remove_file(&path);
            let _stale = UnixListener::bind(&path).unwrap();
        }
        assert!(std::path::Path::new(&path).exists(), "stale socket file should remain");
        // restart on the same path succeeds (probe finds no listener)…
        let p2 = path.clone();
        let worker = thread::spawn(move || {
            let mut t = UdsTransport::connect_with_timeout(&p2, 1, 2, IO).unwrap();
            let mut buf = vec![1.0f32; 2];
            t.all_reduce_sum(&mut buf).unwrap();
        });
        let mut t0 = UdsTransport::listen_with_timeout(&path, 2, IO).unwrap();
        let mut buf = vec![1.0f32; 2];
        t0.all_reduce_sum(&mut buf).unwrap();
        worker.join().unwrap();
        assert_eq!(buf, vec![2.0f32; 2]);
        UdsTransport::cleanup(&path);

        // …but a live listener on the path is refused, not unlinked
        {
            use std::os::unix::net::UnixListener;
            let _live = UnixListener::bind(&path).unwrap();
            let e = UdsTransport::listen_with_timeout(&path, 2, IO).map(|_| ()).unwrap_err();
            let msg = format!("{e:#}");
            assert!(msg.contains("live coordinator"), "{msg}");
        }
        let _ = std::fs::remove_file(&path);
    });
}
