//! Fault-injection suite for the unix-domain-socket transport
//! (`comm/uds.rs`). A distributed run's failure mode must be a
//! contextual `Err` **within the I/O timeout** — never a hang: every
//! scenario here drives a real `UdsTransport` endpoint against a
//! deliberately misbehaving raw-socket peer (`tests/common::rogue`) and
//! every test body runs under a `with_deadline` watchdog, so a
//! regression back to blocking forever fails in seconds.
#![cfg(unix)]

mod common;

use std::thread;
use std::time::Duration;

use csopt::comm::{Transport, UdsTransport};

use common::{rogue, with_deadline};

/// Socket-level I/O timeout for the faulty scenarios: long enough for
/// loopback round-trips, short enough that timeout-path tests are fast.
const IO: Duration = Duration::from_millis(800);
/// Watchdog budget per test body — generous, but finite.
const DEADLINE: Duration = Duration::from_secs(30);

fn sock_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("csopt-fault-{tag}-{}.sock", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

/// Nobody ever connects: the coordinator's handshake must time out with
/// an actionable error instead of waiting forever.
#[test]
fn handshake_timeout_surfaces_err() {
    let path = sock_path("hstimeout");
    let err = with_deadline(DEADLINE, move || {
        let e = UdsTransport::listen_with_timeout(&path, 2, IO).map(|_| ()).unwrap_err();
        UdsTransport::cleanup(&path);
        format!("{e:#}")
    });
    assert!(err.contains("timed out waiting for workers"), "{err}");
}

/// The coordinator never appears: a worker's connect must give up with
/// the socket path in the error.
#[test]
fn connect_timeout_surfaces_err() {
    let path = sock_path("cntimeout");
    let err = with_deadline(DEADLINE, move || {
        let e = UdsTransport::connect_with_timeout(&path, 1, 2, IO).map(|_| ()).unwrap_err();
        format!("{e:#}")
    });
    assert!(err.contains("never came up"), "{err}");
}

/// A peer that promises a 64-byte frame header but ships 10 bytes and
/// goes silent: the coordinator's collective read must fail within the
/// I/O timeout, naming the rank and the op it was receiving.
#[test]
fn truncated_frame_surfaces_err() {
    let path = sock_path("trunc");
    let err = with_deadline(DEADLINE, move || {
        let p2 = path.clone();
        let peer = thread::spawn(move || {
            let mut s = rogue::connect(&p2, DEADLINE);
            rogue::send_hello(&mut s, 1, 2);
            rogue::send_truncated_header(&mut s, 64, 10);
            s // keep the stream open: the fault is silence, not EOF
        });
        let mut t0 = UdsTransport::listen_with_timeout(&path, 2, IO).unwrap();
        let mut buf = vec![0.0f32; 4];
        let e = t0.all_reduce_sum(&mut buf).unwrap_err();
        drop(peer.join().unwrap());
        UdsTransport::cleanup(&path);
        format!("{e:#}")
    });
    assert!(err.contains("receiving allreduce partial from rank 1"), "{err}");
}

/// A header whose `n` promises vastly more payload f32s than the
/// collective's buffer holds: rejected as divergence before any giant
/// allocation or read.
#[test]
fn oversized_payload_header_surfaces_err() {
    let path = sock_path("oversize");
    let err = with_deadline(DEADLINE, move || {
        let p2 = path.clone();
        let peer = thread::spawn(move || {
            let mut s = rogue::connect(&p2, DEADLINE);
            rogue::send_hello(&mut s, 1, 2);
            rogue::send_frame(&mut s, "{\"op\":\"allreduce\",\"n\":1000000}", &[]);
            s
        });
        let mut t0 = UdsTransport::listen_with_timeout(&path, 2, IO).unwrap();
        let mut buf = vec![0.0f32; 4];
        let e = t0.all_reduce_sum(&mut buf).unwrap_err();
        drop(peer.join().unwrap());
        UdsTransport::cleanup(&path);
        format!("{e:#}")
    });
    assert!(err.contains("exceeds the expected 4"), "{err}");
}

/// An implausible header *length* prefix (10 MB of JSON) is rejected
/// outright — a corrupt or hostile length cannot drive the allocation.
#[test]
fn implausible_header_length_surfaces_err() {
    let path = sock_path("hugehdr");
    let err = with_deadline(DEADLINE, move || {
        let p2 = path.clone();
        let peer = thread::spawn(move || {
            let mut s = rogue::connect(&p2, DEADLINE);
            rogue::send_hello(&mut s, 1, 2);
            rogue::send_truncated_header(&mut s, 10_000_000, 16);
            s
        });
        let mut t0 = UdsTransport::listen_with_timeout(&path, 2, IO).unwrap();
        let mut buf = vec![0.0f32; 4];
        let e = t0.all_reduce_sum(&mut buf).unwrap_err();
        drop(peer.join().unwrap());
        UdsTransport::cleanup(&path);
        format!("{e:#}")
    });
    assert!(err.contains("implausible frame header length"), "{err}");
}

/// A worker that vanishes mid-collective (hello, then hangup): the
/// coordinator's all-reduce must surface the broken stream as an error,
/// not wedge the surviving ranks.
#[test]
fn worker_disconnect_mid_allreduce_surfaces_err() {
    let path = sock_path("wdrop");
    let err = with_deadline(DEADLINE, move || {
        let p2 = path.clone();
        let peer = thread::spawn(move || {
            let mut s = rogue::connect(&p2, DEADLINE);
            rogue::send_hello(&mut s, 1, 2);
            // dropping the stream closes it: the coordinator sees EOF
        });
        let mut t0 = UdsTransport::listen_with_timeout(&path, 2, IO).unwrap();
        peer.join().unwrap();
        let mut buf = vec![0.0f32; 4];
        let e = t0.all_reduce_sum(&mut buf).unwrap_err();
        UdsTransport::cleanup(&path);
        format!("{e:#}")
    });
    assert!(err.contains("receiving allreduce partial from rank 1"), "{err}");
}

/// The coordinator dies mid-collective: the *worker* side must error
/// within the timeout too (it is waiting for the reduced result).
#[test]
fn coordinator_disconnect_mid_allreduce_surfaces_err() {
    let path = sock_path("cdrop");
    let err = with_deadline(DEADLINE, move || {
        use std::io::Read;
        use std::os::unix::net::UnixListener;
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path).unwrap();
        let p2 = path.clone();
        let worker = thread::spawn(move || {
            let mut t = UdsTransport::connect_with_timeout(&p2, 1, 2, IO)
                .expect("handshake should complete before the fault");
            let mut buf = vec![1.0f32; 4];
            format!("{:#}", t.all_reduce_sum(&mut buf).unwrap_err())
        });
        // accept the worker, consume its hello frame, then hang up
        let (mut stream, _) = listener.accept().unwrap();
        stream.set_read_timeout(Some(DEADLINE)).unwrap();
        let mut len4 = [0u8; 4];
        stream.read_exact(&mut len4).unwrap();
        let mut hello = vec![0u8; u32::from_le_bytes(len4) as usize];
        stream.read_exact(&mut hello).unwrap();
        drop(stream);
        drop(listener);
        let e = worker.join().unwrap();
        let _ = std::fs::remove_file(&path);
        e
    });
    // the worker fails on the partial write (broken pipe) or on reading
    // the result (EOF/timeout) depending on kernel buffering — either
    // way it is a contextual rank-1 allreduce error, not a hang
    assert!(err.contains("rank 1") && err.contains("allreduce"), "{err}");
}

/// A peer whose op sequence diverges from the coordinator's (it answers
/// the allreduce with a barrier frame) is called out as divergence.
#[test]
fn diverged_op_sequence_surfaces_err() {
    let path = sock_path("diverge");
    let err = with_deadline(DEADLINE, move || {
        let p2 = path.clone();
        let peer = thread::spawn(move || {
            let mut s = rogue::connect(&p2, DEADLINE);
            rogue::send_hello(&mut s, 1, 2);
            rogue::send_frame(&mut s, "{\"op\":\"barrier\",\"n\":0}", &[]);
            s
        });
        let mut t0 = UdsTransport::listen_with_timeout(&path, 2, IO).unwrap();
        let mut buf = vec![0.0f32; 4];
        let e = t0.all_reduce_sum(&mut buf).unwrap_err();
        drop(peer.join().unwrap());
        UdsTransport::cleanup(&path);
        format!("{e:#}")
    });
    assert!(err.contains("diverged"), "{err}");
}

/// Sanity leg: with a *well-behaved* peer the short-timeout transport
/// still completes collectives — the fault tests above fail because of
/// the injected faults, not because the timeout is unrealistically low.
#[test]
fn short_timeout_still_completes_honest_collectives() {
    let path = sock_path("honest");
    with_deadline(DEADLINE, move || {
        let p2 = path.clone();
        let worker = thread::spawn(move || {
            let mut t = UdsTransport::connect_with_timeout(&p2, 1, 2, IO).unwrap();
            let mut buf = vec![2.0f32; 3];
            t.all_reduce_sum(&mut buf).unwrap();
            t.barrier().unwrap();
            buf
        });
        let mut t0 = UdsTransport::listen_with_timeout(&path, 2, IO).unwrap();
        let mut buf = vec![1.0f32; 3];
        t0.all_reduce_sum(&mut buf).unwrap();
        t0.barrier().unwrap();
        let wbuf = worker.join().unwrap();
        UdsTransport::cleanup(&path);
        assert_eq!(buf, vec![3.0f32; 3]);
        assert_eq!(wbuf, vec![3.0f32; 3]);
    });
}
