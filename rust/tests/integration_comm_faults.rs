//! Fault-injection suite for the socket transports (`comm/uds.rs` +
//! `comm/tcp.rs`). A distributed run's failure mode must be a
//! contextual `Err` **within the I/O timeout** — never a hang: every
//! scenario drives a real transport endpoint against a deliberately
//! misbehaving raw-socket peer (`tests/common::rogue`) and every test
//! body runs under a `with_deadline` watchdog, so a regression back to
//! blocking forever fails in seconds.
//!
//! Both transports share the frame codec (`comm/frame.rs`), so the
//! rogue scenarios are parameterized over the wire: each fault runs
//! once per socket family and must surface the *same* error text —
//! the serve loop's recovery logic keys off these messages regardless
//! of transport.
#![cfg(unix)]

mod common;

use std::net::TcpListener;
use std::thread;
use std::time::Duration;

use csopt::comm::{TcpTransport, Transport, UdsTransport};

use common::{rogue, with_deadline};

/// Socket-level I/O timeout for the faulty scenarios: long enough for
/// loopback round-trips, short enough that timeout-path tests are fast.
const IO: Duration = Duration::from_millis(800);
/// Watchdog budget per test body — generous, but finite.
const DEADLINE: Duration = Duration::from_secs(30);

fn sock_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("csopt-fault-{tag}-{}.sock", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

#[derive(Clone, Copy, Debug)]
enum Wire {
    Uds,
    Tcp,
}

/// A coordinator endpoint of either family. TCP binds eagerly (port 0 →
/// the OS picks; rogue peers get the resolved address); UDS binds
/// inside `accept` and the rogue's connect retry covers the gap.
struct Coord {
    ep: String,
    tcp: Option<TcpListener>,
}

impl Coord {
    fn bind(wire: Wire, tag: &str) -> Coord {
        match wire {
            Wire::Uds => Coord { ep: sock_path(tag), tcp: None },
            Wire::Tcp => {
                let l = TcpListener::bind("127.0.0.1:0").unwrap();
                let ep = l.local_addr().unwrap().to_string();
                Coord { ep, tcp: Some(l) }
            }
        }
    }

    /// Rank 0's side: wait for `world - 1` hellos under the short
    /// timeout, behind the shared `Transport` face.
    fn accept(&self, world: usize) -> csopt::Result<Box<dyn Transport>> {
        match &self.tcp {
            None => UdsTransport::listen_with_timeout(&self.ep, world, IO)
                .map(|t| Box::new(t) as Box<dyn Transport>),
            Some(l) => TcpTransport::accept_world(l, &self.ep, world, IO)
                .map(|t| Box::new(t) as Box<dyn Transport>),
        }
    }

    fn cleanup(&self) {
        if self.tcp.is_none() {
            UdsTransport::cleanup(&self.ep);
        }
    }
}

/// Run one rogue-peer scenario: `fault` drives the misbehaving side
/// against the coordinator's 2-rank accept + allreduce, and the
/// coordinator's error text is returned for the per-wire assertion.
fn rogue_scenario(
    wire: Wire,
    tag: &str,
    fault: impl FnOnce(&mut rogue::Conn) + Send + 'static,
) -> String {
    let coord = Coord::bind(wire, tag);
    with_deadline(DEADLINE, move || {
        let ep = coord.ep.clone();
        let peer = thread::spawn(move || {
            let mut s = rogue::connect(&ep, DEADLINE);
            rogue::send_hello(&mut s, 1, 2);
            fault(&mut s);
            s // keep the stream alive until the coordinator has failed
        });
        let mut t0 = coord.accept(2).unwrap();
        let mut buf = vec![0.0f32; 4];
        let e = t0.all_reduce_sum(&mut buf).unwrap_err();
        drop(peer.join().unwrap());
        coord.cleanup();
        format!("{e:#}")
    })
}

/// Nobody ever connects: the coordinator's handshake must time out with
/// an actionable error instead of waiting forever.
fn handshake_timeout(wire: Wire) {
    let coord = Coord::bind(wire, "hstimeout");
    let err = with_deadline(DEADLINE, move || {
        let e = coord.accept(2).map(|_| ()).unwrap_err();
        coord.cleanup();
        format!("{e:#}")
    });
    assert!(err.contains("timed out waiting for workers"), "[{wire:?}] {err}");
}

#[test]
fn handshake_timeout_surfaces_err_uds() {
    handshake_timeout(Wire::Uds);
}

#[test]
fn handshake_timeout_surfaces_err_tcp() {
    handshake_timeout(Wire::Tcp);
}

/// The coordinator never appears: a worker's connect must give up with
/// the endpoint in the error. (The TCP leg binds a port and drops it, so
/// connects are refused rather than swallowed.)
fn connect_timeout(wire: Wire) {
    let ep = match wire {
        Wire::Uds => sock_path("cntimeout"),
        Wire::Tcp => {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
            // listener drops here — the port refuses from now on
        }
    };
    let err = with_deadline(DEADLINE, move || {
        let e = match wire {
            Wire::Uds => {
                UdsTransport::connect_with_timeout(&ep, 1, 2, IO).map(|_| ()).unwrap_err()
            }
            Wire::Tcp => {
                TcpTransport::connect_with_timeout(&ep, 1, 2, IO).map(|_| ()).unwrap_err()
            }
        };
        format!("{e:#}")
    });
    assert!(err.contains("never came up"), "[{wire:?}] {err}");
}

#[test]
fn connect_timeout_surfaces_err_uds() {
    connect_timeout(Wire::Uds);
}

#[test]
fn connect_timeout_surfaces_err_tcp() {
    connect_timeout(Wire::Tcp);
}

/// A peer that promises a 64-byte frame header but ships 10 bytes and
/// goes silent: the coordinator's collective read must fail within the
/// I/O timeout, naming the rank and the op it was receiving.
fn truncated_frame(wire: Wire) {
    let err = rogue_scenario(wire, "trunc", |s| {
        rogue::send_truncated_header(s, 64, 10);
    });
    assert!(err.contains("receiving allreduce partial from rank 1"), "[{wire:?}] {err}");
}

#[test]
fn truncated_frame_surfaces_err_uds() {
    truncated_frame(Wire::Uds);
}

#[test]
fn truncated_frame_surfaces_err_tcp() {
    truncated_frame(Wire::Tcp);
}

/// A header whose `n` promises vastly more payload f32s than the
/// collective's buffer holds: rejected as divergence before any giant
/// allocation or read.
fn oversized_payload_header(wire: Wire) {
    let err = rogue_scenario(wire, "oversize", |s| {
        rogue::send_frame(s, "{\"op\":\"allreduce\",\"n\":1000000}", &[]);
    });
    assert!(err.contains("exceeds the expected 4"), "[{wire:?}] {err}");
}

#[test]
fn oversized_payload_header_surfaces_err_uds() {
    oversized_payload_header(Wire::Uds);
}

#[test]
fn oversized_payload_header_surfaces_err_tcp() {
    oversized_payload_header(Wire::Tcp);
}

/// An implausible header *length* prefix (10 MB of JSON) is rejected
/// outright — a corrupt or hostile length cannot drive the allocation.
fn implausible_header_length(wire: Wire) {
    let err = rogue_scenario(wire, "hugehdr", |s| {
        rogue::send_truncated_header(s, 10_000_000, 16);
    });
    assert!(err.contains("implausible frame header length"), "[{wire:?}] {err}");
}

#[test]
fn implausible_header_length_surfaces_err_uds() {
    implausible_header_length(Wire::Uds);
}

#[test]
fn implausible_header_length_surfaces_err_tcp() {
    implausible_header_length(Wire::Tcp);
}

/// A worker that vanishes mid-collective (hello, then hangup): the
/// coordinator's all-reduce must surface the broken stream as an error,
/// not wedge the surviving ranks. This is the exact fault the serve
/// loop turns into a stall-and-resume restart (DESIGN.md §13).
fn worker_disconnect_mid_allreduce(wire: Wire) {
    let coord = Coord::bind(wire, "wdrop");
    let err = with_deadline(DEADLINE, move || {
        let ep = coord.ep.clone();
        let peer = thread::spawn(move || {
            let mut s = rogue::connect(&ep, DEADLINE);
            rogue::send_hello(&mut s, 1, 2);
            // dropping the stream closes it: the coordinator sees EOF
        });
        let mut t0 = coord.accept(2).unwrap();
        peer.join().unwrap();
        let mut buf = vec![0.0f32; 4];
        let e = t0.all_reduce_sum(&mut buf).unwrap_err();
        coord.cleanup();
        format!("{e:#}")
    });
    assert!(err.contains("receiving allreduce partial from rank 1"), "[{wire:?}] {err}");
}

#[test]
fn worker_disconnect_mid_allreduce_surfaces_err_uds() {
    worker_disconnect_mid_allreduce(Wire::Uds);
}

#[test]
fn worker_disconnect_mid_allreduce_surfaces_err_tcp() {
    worker_disconnect_mid_allreduce(Wire::Tcp);
}

/// A peer whose op sequence diverges from the coordinator's (it answers
/// the allreduce with a barrier frame) is called out as divergence.
fn diverged_op_sequence(wire: Wire) {
    let err = rogue_scenario(wire, "diverge", |s| {
        rogue::send_frame(s, "{\"op\":\"barrier\",\"n\":0}", &[]);
    });
    assert!(err.contains("diverged"), "[{wire:?}] {err}");
}

#[test]
fn diverged_op_sequence_surfaces_err_uds() {
    diverged_op_sequence(Wire::Uds);
}

#[test]
fn diverged_op_sequence_surfaces_err_tcp() {
    diverged_op_sequence(Wire::Tcp);
}

/// The coordinator dies mid-collective: the *worker* side must error
/// within the timeout too (it is waiting for the reduced result).
#[test]
fn coordinator_disconnect_mid_allreduce_surfaces_err() {
    let path = sock_path("cdrop");
    let err = with_deadline(DEADLINE, move || {
        use std::io::Read;
        use std::os::unix::net::UnixListener;
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path).unwrap();
        let p2 = path.clone();
        let worker = thread::spawn(move || {
            let mut t = UdsTransport::connect_with_timeout(&p2, 1, 2, IO)
                .expect("handshake should complete before the fault");
            let mut buf = vec![1.0f32; 4];
            format!("{:#}", t.all_reduce_sum(&mut buf).unwrap_err())
        });
        // accept the worker, consume its hello frame, then hang up
        let (mut stream, _) = listener.accept().unwrap();
        stream.set_read_timeout(Some(DEADLINE)).unwrap();
        let mut len4 = [0u8; 4];
        stream.read_exact(&mut len4).unwrap();
        let mut hello = vec![0u8; u32::from_le_bytes(len4) as usize];
        stream.read_exact(&mut hello).unwrap();
        drop(stream);
        drop(listener);
        let e = worker.join().unwrap();
        let _ = std::fs::remove_file(&path);
        e
    });
    // the worker fails on the partial write (broken pipe) or on reading
    // the result (EOF/timeout) depending on kernel buffering — either
    // way it is a contextual rank-1 allreduce error, not a hang
    assert!(err.contains("rank 1") && err.contains("allreduce"), "{err}");
}

/// Sanity leg: with a *well-behaved* peer the short-timeout transport
/// still completes collectives — the fault tests above fail because of
/// the injected faults, not because the timeout is unrealistically low.
/// (The TCP equivalent lives in `comm/tcp.rs`'s unit tests.)
#[test]
fn short_timeout_still_completes_honest_collectives() {
    let path = sock_path("honest");
    with_deadline(DEADLINE, move || {
        let p2 = path.clone();
        let worker = thread::spawn(move || {
            let mut t = UdsTransport::connect_with_timeout(&p2, 1, 2, IO).unwrap();
            let mut buf = vec![2.0f32; 3];
            t.all_reduce_sum(&mut buf).unwrap();
            t.barrier().unwrap();
            buf
        });
        let mut t0 = UdsTransport::listen_with_timeout(&path, 2, IO).unwrap();
        let mut buf = vec![1.0f32; 3];
        t0.all_reduce_sum(&mut buf).unwrap();
        t0.barrier().unwrap();
        let wbuf = worker.join().unwrap();
        UdsTransport::cleanup(&path);
        assert_eq!(buf, vec![3.0f32; 3]);
        assert_eq!(wbuf, vec![3.0f32; 3]);
    });
}

/// A stale socket file from a crashed coordinator must not block a
/// restart (remove-then-bind with a liveness probe), while a *live*
/// coordinator on the same path is refused instead of hijacked.
#[test]
fn stale_socket_cleanup_vs_live_coordinator() {
    let path = sock_path("stale");
    with_deadline(DEADLINE, move || {
        // a dead coordinator's leftover: bind and drop, keeping the file
        {
            use std::os::unix::net::UnixListener;
            let _ = std::fs::remove_file(&path);
            let _stale = UnixListener::bind(&path).unwrap();
        }
        assert!(std::path::Path::new(&path).exists(), "stale socket file should remain");
        // restart on the same path succeeds (probe finds no listener)…
        let p2 = path.clone();
        let worker = thread::spawn(move || {
            let mut t = UdsTransport::connect_with_timeout(&p2, 1, 2, IO).unwrap();
            let mut buf = vec![1.0f32; 2];
            t.all_reduce_sum(&mut buf).unwrap();
        });
        let mut t0 = UdsTransport::listen_with_timeout(&path, 2, IO).unwrap();
        let mut buf = vec![1.0f32; 2];
        t0.all_reduce_sum(&mut buf).unwrap();
        worker.join().unwrap();
        assert_eq!(buf, vec![2.0f32; 2]);
        UdsTransport::cleanup(&path);

        // …but a live listener on the path is refused, not unlinked
        {
            use std::os::unix::net::UnixListener;
            let _live = UnixListener::bind(&path).unwrap();
            let e = UdsTransport::listen_with_timeout(&path, 2, IO).map(|_| ()).unwrap_err();
            let msg = format!("{e:#}");
            assert!(msg.contains("live coordinator"), "{msg}");
        }
        let _ = std::fs::remove_file(&path);
    });
}
