//! Hot-path microbenches: count-sketch UPDATE/QUERY (rehash vs planned),
//! the fused optimizer steps and their shard scaling, at paper-like
//! shapes. Feeds the DESIGN.md §Perf ledger (`results/bench.csv` +
//! `results/bench.json`).

use csopt::optim::{OptimSpec, RowOptimizer, RowShape};
use csopt::sketch::{CountMinSketch, CountSketch};
use csopt::util::bench::{black_box, Bench};
use csopt::util::rng::Rng;

fn ids_and_grads(n: usize, k: usize, d: usize, seed: u64) -> (Vec<u64>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let ids: Vec<u64> = rng.sample_distinct(n, k).into_iter().map(|x| x as u64).collect();
    let grads: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    (ids, grads)
}

fn main() {
    let mut b = Bench::from_env("sketch");

    // paper-like shape: wt103 embedding layer (d=256, w=6554, v=3)
    for &(k, d, w) in &[(256usize, 64usize, 2048usize), (1152, 256, 6554)] {
        let (ids, grads) = ids_and_grads(32_768, k, d, 1);
        let mut cs = CountSketch::new(3, w, d, 7);
        b.bench(&format!("cs_update/k{k}.d{d}.w{w}"), || {
            cs.update(&ids, &grads);
            black_box(&cs);
        });
        let mut out = vec![0.0f32; k * d];
        b.bench(&format!("cs_query/k{k}.d{d}.w{w}"), || {
            cs.query(&ids, &mut out);
            black_box(&out);
        });
        // planned counterparts: hash once, replay (DESIGN.md §2)
        let plan = cs.plan(&ids);
        b.bench(&format!("cs_update_planned/k{k}.d{d}.w{w}"), || {
            cs.update_with(&plan, &grads);
            black_box(&cs);
        });
        b.bench(&format!("cs_query_planned/k{k}.d{d}.w{w}"), || {
            cs.query_with(&plan, &mut out);
            black_box(&out);
        });
        let mut cms = CountMinSketch::new(3, w, d, 7);
        b.bench(&format!("cms_update/k{k}.d{d}.w{w}"), || {
            cms.update(&ids, &grads);
            black_box(&cms);
        });
        b.bench(&format!("cms_query/k{k}.d{d}.w{w}"), || {
            cms.query(&ids, &mut out);
            black_box(&out);
        });
        let plan = cms.plan(&ids);
        b.bench(&format!("cms_update_planned/k{k}.d{d}.w{w}"), || {
            cms.update_with(&plan, &grads);
            black_box(&cms);
        });
    }

    // fused optimizer steps vs the dense baseline (k=1152, d=256 = wt103),
    // all built through the unified OptimSpec API
    let (k, d, n, w) = (1152usize, 256usize, 32_768usize, 6554usize);
    let (ids, grads) = ids_and_grads(n, k, d, 2);
    let mut rows = vec![0.5f32; k * d];
    let shape = RowShape::new(n, d).with_sketch(3, w);
    let build = |s: &str| -> Box<dyn RowOptimizer> {
        OptimSpec::parse(s).unwrap().build_row(&shape, None).unwrap()
    };

    // rehash baseline: the same QUERY → Δ → UPDATE → re-QUERY sequence as
    // cs-adam's step, but through the id-based entry points, i.e. six hash
    // passes per step instead of one (the pre-plan execution profile)
    {
        let mut sk_m = CountSketch::new(3, w, d, 7);
        let mut sk_v = CountMinSketch::new(3, w, d, 7);
        let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
        let mut est_m = vec![0.0f32; k * d];
        let mut est_v = vec![0.0f32; k * d];
        let mut delta = vec![0.0f32; k * d];
        let mut t = 0usize;
        b.bench("step/cs_adam_rehash.k1152.d256", || {
            t += 1;
            sk_m.query(&ids, &mut est_m);
            for i in 0..k * d {
                delta[i] = (1.0 - b1) * (grads[i] - est_m[i]);
            }
            sk_m.update(&ids, &delta);
            sk_m.query(&ids, &mut est_m);
            sk_v.query(&ids, &mut est_v);
            for i in 0..k * d {
                delta[i] = (1.0 - b2) * (grads[i] * grads[i] - est_v[i]);
            }
            sk_v.update(&ids, &delta);
            sk_v.query(&ids, &mut est_v);
            let bc1 = 1.0 - b1.powi(t as i32);
            let bc2 = 1.0 - b2.powi(t as i32);
            for i in 0..k * d {
                let m_hat = est_m[i] / bc1;
                let v_hat = est_v[i].max(0.0) / bc2;
                rows[i] -= 1e-3 * m_hat / (v_hat.sqrt() + eps);
            }
            black_box(&rows);
        });
    }

    // planned-but-unfused reference: hash once, then run the pre-fusion
    // execution profile — six separate plan traversals (QUERY → Δ →
    // UPDATE → re-QUERY for each of m and v). The gap between this row
    // and step/cs_adam below is what the fused kernel (DESIGN.md §12)
    // buys at fixed hashing cost.
    {
        let mut sk_m = CountSketch::new(3, w, d, 7);
        let mut sk_v = CountMinSketch::new(3, w, d, 7);
        let plan = sk_m.plan(&ids);
        let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
        let mut est_m = vec![0.0f32; k * d];
        let mut est_v = vec![0.0f32; k * d];
        let mut delta = vec![0.0f32; k * d];
        let mut t = 0usize;
        b.bench("step/cs_adam_unfused.k1152.d256", || {
            t += 1;
            sk_m.query_with(&plan, &mut est_m);
            for i in 0..k * d {
                delta[i] = (1.0 - b1) * (grads[i] - est_m[i]);
            }
            sk_m.update_with(&plan, &delta);
            sk_m.query_with(&plan, &mut est_m);
            sk_v.query_with(&plan, &mut est_v);
            for i in 0..k * d {
                delta[i] = (1.0 - b2) * (grads[i] * grads[i] - est_v[i]);
            }
            sk_v.update_with(&plan, &delta);
            sk_v.query_with(&plan, &mut est_v);
            let bc1 = 1.0 - b1.powi(t as i32);
            let bc2 = 1.0 - b2.powi(t as i32);
            for i in 0..k * d {
                let m_hat = est_m[i] / bc1;
                let v_hat = est_v[i].max(0.0) / bc2;
                rows[i] -= 1e-3 * m_hat / (v_hat.sqrt() + eps);
            }
            black_box(&rows);
        });
    }

    // planned single-threaded step (must beat the rehash row above), then
    // shard scaling at the same shape (DESIGN.md §5)
    for spec in ["cs-adam@seed=7", "cs-adam@seed=7,shard=2", "cs-adam@seed=7,shard=4"] {
        let mut opt = build(spec);
        let label = match OptimSpec::parse(spec).unwrap().shards {
            None => "step/cs_adam.k1152.d256".to_string(),
            Some(s) => format!("step/cs_adam.k1152.d256.shard{s}"),
        };
        let mut t = 0usize;
        b.bench(&label, || {
            t += 1;
            opt.step_rows(&ids, &mut rows, &grads, 1e-3, t);
            black_box(&rows);
        });
    }

    let mut dense_adam = build("adam");
    let mut t = 0usize;
    b.bench("step/dense_adam.k1152.d256", || {
        t += 1;
        dense_adam.step_rows(&ids, &mut rows, &grads, 1e-3, t);
        black_box(&rows);
    });

    let mut cs_mom = build("cs-momentum@seed=7");
    b.bench("step/cs_momentum.k1152.d256", || {
        cs_mom.step_rows(&ids, &mut rows, &grads, 1e-3, 1);
        black_box(&rows);
    });

    let mut cms_ada = build("cs-adagrad@seed=7");
    b.bench("step/cms_adagrad.k1152.d256", || {
        cms_ada.step_rows(&ids, &mut rows, &grads, 1e-3, 1);
        black_box(&rows);
    });

    // small-sketch shard scaling: with the persistent `parallel_map` pool
    // (no spawn+join per call) shard>1 must track the sequential row at
    // this size instead of losing tens of µs to thread spawns — the
    // regression guard for DESIGN.md §Perf's "small-sketch sharding" row
    {
        let (k, d, w) = (256usize, 32usize, 512usize);
        let (ids, grads) = ids_and_grads(4096, k, d, 3);
        for shards in [1usize, 2, 4] {
            let mut cs = CountSketch::new(3, w, d, 7).with_shards(shards);
            let plan = cs.plan(&ids);
            b.bench(&format!("cs_update_small/k{k}.d{d}.w{w}.shard{shards}"), || {
                cs.update_with(&plan, &grads);
                black_box(&cs);
            });
            let mut out = vec![0.0f32; k * d];
            b.bench(&format!("cs_query_small/k{k}.d{d}.w{w}.shard{shards}"), || {
                cs.query_with(&plan, &mut out);
                black_box(&out);
            });
        }
    }

    // tiny-batch steps: k·d here is below SERIAL_MIN_KD, so the fused
    // kernel must run its serial fast path — shard4 tracking the
    // sequential row (instead of paying pool dispatch per phase) is the
    // regression pin for that threshold
    {
        let (k, d, w, n) = (16usize, 32usize, 512usize, 4096usize);
        let (ids, grads) = ids_and_grads(n, k, d, 5);
        let mut rows = vec![0.5f32; k * d];
        let shape = RowShape::new(n, d).with_sketch(3, w);
        for spec in ["cs-adam@seed=7", "cs-adam@seed=7,shard=4"] {
            let mut opt = OptimSpec::parse(spec).unwrap().build_row(&shape, None).unwrap();
            let label = match OptimSpec::parse(spec).unwrap().shards {
                None => "step/cs_adam.k16.d32".to_string(),
                Some(s) => format!("step/cs_adam.k16.d32.shard{s}"),
            };
            let mut t = 0usize;
            b.bench(&label, || {
                t += 1;
                opt.step_rows(&ids, &mut rows, &grads, 1e-3, t);
                black_box(&rows);
            });
        }
    }

    // fold + clean maintenance ops (the decay loop is the blocked
    // `scale_in_place` kernel; w16384 doubles the footprint to keep the
    // row memory-bound like the training-scale clean)
    let mut cs = CountSketch::new(3, 8192, 256, 9);
    b.bench("maintenance/clean.w8192.d256", || {
        cs.tensor_mut().scale(0.5);
        black_box(&cs);
    });
    let mut cs = CountSketch::new(3, 16_384, 256, 9);
    b.bench("maintenance/clean.w16384.d256", || {
        cs.tensor_mut().scale(0.5);
        black_box(&cs);
    });

    // streaming clean (DESIGN.md §15): a quantized store's `scale` defers
    // the sweep — rows pay catch-up on their next touch, and a full flush
    // runs only every MAX_PENDING_CLEANS scales. Each iteration is one
    // clean plus one 256-row touch; the w16384 ↔ w65536 pair shows the
    // per-clean cost tracking the *active* rows (plus the amortized 1/32
    // flush) instead of the full width the eager rows above sweep.
    {
        use csopt::sketch::{CellFormat, QuantizedStore, SketchHasher, SketchPlan, SketchStore};
        let (k, d) = (256usize, 256usize);
        let mut rng = Rng::new(10);
        let deltas: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for w in [16_384usize, 65_536] {
            let mut st = QuantizedStore::zeros(CellFormat::Bf16, 3, w, d);
            let hasher = SketchHasher::new(3, w, 9);
            let ids: Vec<u64> = (0..k as u64).collect();
            let plan = SketchPlan::build(&hasher, &ids);
            st.update(&plan, &deltas, true);
            b.bench(&format!("maintenance/clean_active.w{w}.d{d}"), || {
                st.scale(0.99);
                st.update(&plan, &deltas, true);
                black_box(&st);
            });
        }
    }

    // quantized optimizer step (DESIGN.md §15): the accumulate-in-f32 /
    // round-once-per-batch bf16 store under the full cs-adam step, at the
    // CI-smoke shape — pins the decode/encode tax of quantized cells.
    {
        let (k, d, n, w) = (256usize, 64usize, 32_768usize, 2048usize);
        let (ids, grads) = ids_and_grads(n, k, d, 11);
        let mut rows = vec![0.5f32; k * d];
        let shape = RowShape::new(n, d).with_sketch(3, w);
        let mut opt =
            OptimSpec::parse("cs-adam@seed=7,cells=bf16").unwrap().build_row(&shape, None).unwrap();
        let mut t = 0usize;
        b.bench("step/quant_step.bf16.k256.d64", || {
            t += 1;
            opt.step_rows(&ids, &mut rows, &grads, 1e-3, t);
            black_box(&rows);
        });
    }

    // comm-sketch wire compressor (DESIGN.md §11): per-step encode of a
    // tiny-preset-like embedding segment (4096 live coords into a
    // [d, w] wire sketch) and the mask-bounded top-k decode, at the
    // default and a widened geometry
    {
        use csopt::comm::SegmentSketcher;
        let mut rng = Rng::new(4);
        let n_cand = 8192usize;
        let cand: Vec<u64> = (0..n_cand as u64).collect();
        let live: Vec<u64> =
            rng.sample_distinct(n_cand, 4096).into_iter().map(|x| x as u64).collect();
        let vals: Vec<f32> = (0..live.len()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for &(w, d) in &[(1024usize, 3usize), (2048, 3)] {
            let mut sk = SegmentSketcher::new(d, w, 11);
            let mut wire = vec![0.0f32; sk.sketch_len()];
            b.bench(&format!("comm_encode.w{w}.d{d}"), || {
                wire.iter_mut().for_each(|x| *x = 0.0);
                sk.encode(&live, &vals, &mut wire);
                black_box(&wire);
            });
        }
        for &k in &[256usize, 1024] {
            let mut sk = SegmentSketcher::new(3, 1024, 11);
            let mut wire = vec![0.0f32; sk.sketch_len()];
            sk.encode(&live, &vals, &mut wire);
            let (mut rec_ids, mut rec_vals) = (Vec::new(), Vec::new());
            b.bench(&format!("comm_decode.k{k}"), || {
                sk.decode(&wire, 0.9, &cand, k, &mut rec_ids, &mut rec_vals);
                black_box(&rec_ids);
            });
        }
    }

    // serve read path (DESIGN.md §13): one full client request against a
    // resident QueryServer — connect, frame roundtrip, answer from the
    // published epoch snapshot — for 64 parameter rows and 64 sketch-row
    // materializations at a wide-sketch shape. This is the per-request
    // latency a `csopt query` client pays, socket included.
    {
        use csopt::optim::AuxSketch;
        use csopt::serve::query::{client_ping, client_rows, QueryServer, ServeSnapshot};
        let (w, d, nrows) = (4096usize, 256usize, 64usize);
        let mut sk = CountSketch::new(3, w, d, 13);
        let (ids, grads) = ids_and_grads(8192, 1024, d, 6);
        sk.update(&ids, &grads);
        let mut layers = std::collections::BTreeMap::new();
        layers.insert("emb".to_string(), (d, vec![0.25f32; w * d]));
        let addr = std::env::temp_dir()
            .join(format!("csopt-bench-q-{}.sock", std::process::id()))
            .display()
            .to_string();
        let server = QueryServer::start(&addr).expect("starting bench query server");
        server.publish(ServeSnapshot {
            epoch: 1,
            step: 1,
            valid_ppl: 0.0,
            layers,
            sketches: vec![("emb.m".to_string(), AuxSketch::Signed(sk))],
        });
        // publish is a channel send — wait (bounded) until the server answers
        let mut up = false;
        for _ in 0..1000 {
            if client_ping(&addr).is_ok() {
                up = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(up, "bench query server never came up on {addr}");
        let rows: Vec<u64> = (0..nrows as u64).collect();
        b.bench("serve_query.w4096.d256", || {
            let r = client_rows(&addr, "query", "emb", &rows).unwrap();
            black_box(&r);
        });
        b.bench("serve_materialize.w4096.d256", || {
            let r = client_rows(&addr, "materialize", "emb.m", &rows).unwrap();
            black_box(&r);
        });
        drop(server);
    }

    // sparse collectives (DESIGN.md §14): the owned-rows frame codec at a
    // wire-realistic shape (4096 rows × d=64 ≈ 1 MB frame) — the per-step
    // encode/decode tax the sparse exchange pays instead of shipping the
    // dense buffer — plus the solo-world collective entry points, which
    // bound the transport-side bookkeeping at zero rendezvous cost.
    {
        use csopt::comm::frame::{read_rows_frame, write_rows_frame};
        use csopt::comm::{mem_world, Transport};
        use std::io::Cursor;
        let (nrows, d, id_space) = (4096usize, 64usize, 65_536usize);
        let mut rng = Rng::new(8);
        let mut ids: Vec<u64> =
            rng.sample_distinct(id_space, nrows).into_iter().map(|x| x as u64).collect();
        ids.sort_unstable();
        let payload: Vec<f32> = (0..nrows * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut wire = Vec::with_capacity(nrows * (8 + d * 4) + 64);
        b.bench(&format!("comm_rows_encode.r{nrows}.d{d}"), || {
            wire.clear();
            write_rows_frame(&mut wire, "gatherrows", &ids, &payload, d, id_space).unwrap();
            black_box(&wire);
        });
        let (mut got_ids, mut got_rows) = (Vec::new(), Vec::new());
        b.bench(&format!("comm_rows_decode.r{nrows}.d{d}"), || {
            let mut cur = Cursor::new(&wire[..]);
            read_rows_frame(&mut cur, &mut got_ids, &mut got_rows, d, id_space, id_space)
                .unwrap();
            black_box(&got_ids);
        });
        let mut t = mem_world(1).pop().unwrap();
        let mut buf = vec![1.0f32; nrows * d];
        b.bench(&format!("comm_rs.n{}", nrows * d), || {
            t.reduce_scatter_sum(&mut buf, d).unwrap();
            black_box(&buf);
        });
        b.bench(&format!("comm_ag.n{}", nrows * d), || {
            t.all_gather(&mut buf, d).unwrap();
            black_box(&buf);
        });
        b.bench(&format!("comm_ag_rows.r{nrows}.d{d}"), || {
            t.all_gather_rows(&ids, &payload, d, id_space, &mut got_ids, &mut got_rows).unwrap();
            black_box(&got_ids);
        });
    }

    b.finish();
}
