//! Runtime benches: PJRT dispatch overhead and the end-to-end train-step
//! cost for both engines and both optimizer paths — the Table 5/6 "time"
//! columns at micro scale. Requires `make artifacts`.

use csopt::config::lm_preset;
use csopt::exp::common::corpus_for;
use csopt::optim::{OptimPolicy, OptimSpec};
use csopt::runtime::{Arg, Runtime};
use csopt::train::engine::{LmEngine, RustLmEngine, XlaLmEngine};
use csopt::train::trainer::{LmTrainer, TrainerOptions};
use csopt::util::bench::{black_box, Bench};
use csopt::util::rng::Rng;

fn main() {
    let dir = std::env::var("CSOPT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let Ok(rt) = Runtime::open(&dir) else {
        eprintln!("skipping bench_runtime: no artifacts at {dir} (run `make artifacts`)");
        return;
    };
    let mut b = Bench::from_env("runtime");

    // raw dispatch overhead: trivial graph round-trip
    let axpy = rt.load("smoke.axpy").unwrap();
    let x = [1.0f32, 2.0, 3.0, 4.0];
    b.bench("dispatch/axpy_roundtrip", || {
        let outs = axpy.call(&[Arg::ScalarF32(2.0), Arg::F32(&x)]).unwrap();
        black_box(outs.len());
    });

    // end-to-end tiny train step, rust vs xla engine, sketch vs sketch-xla
    let preset = lm_preset("tiny").unwrap();
    let corpus = corpus_for(&preset, 16, 5);
    let (train, _, _) = corpus.split(0.05, 0.05);
    let mut batcher = csopt::data::batcher::BpttBatcher::new(train, preset.batch, preset.bptt);
    let batch = batcher.next_batch().unwrap();

    for (label, engine, emb) in [
        ("train_step/rust+sketch", "rust", "cs-adam"),
        ("train_step/xla+sketch", "xla", "cs-adam"),
        ("train_step/xla+sketch-xla", "xla", "xla-cs-adam"),
    ] {
        let emb = OptimSpec::parse(emb).unwrap();
        let opts =
            TrainerOptions::with_policy(preset, OptimPolicy::pair(emb, emb.as_dense()), 1e-3);
        let mut rng = Rng::new(1);
        let eng: Box<dyn LmEngine> = if engine == "rust" {
            Box::new(RustLmEngine::new(preset, &mut rng))
        } else {
            Box::new(XlaLmEngine::new(preset, &rt, &mut rng).unwrap())
        };
        let mut tr = LmTrainer::new(opts, eng, Some(&rt)).unwrap();
        b.bench(label, || {
            let loss = tr.train_step(&batch.x, &batch.y).unwrap();
            black_box(loss);
        });
    }

    b.finish();
}
