//! Pure-Rust model-engine benches: matmul kernels, LSTM step, full LM
//! train step — identifies the L3 compute bottlenecks for §Perf.

use csopt::model::linalg::{mm, mm_at, mm_bt};
use csopt::model::{LmGrads, LmModel};
use csopt::util::bench::{black_box, Bench};
use csopt::util::rng::Rng;

fn main() {
    let mut b = Bench::from_env("model");
    let mut rng = Rng::new(1);

    // matmul shapes from the tiny/wt103 presets
    for &(m, k, n, label) in &[
        (32usize, 64usize, 256usize, "mm/32x64x256"),
        (1120, 512, 2048, "mm/1120x512x2048"),
    ] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let bb: Vec<f32> = (0..k * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut out = vec![0.0f32; m * n];
        b.bench(label, || {
            mm(&a, &bb, m, k, n, &mut out, false);
            black_box(&out);
        });
        let mut out2 = vec![0.0f32; k * n];
        let at: Vec<f32> = (0..m * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        b.bench(&format!("{label}.at"), || {
            mm_at(&at[..m * k.min(at.len() / m)], &a[..m * (k.min(a.len() / m))], m, k, k, &mut out2[..k * k], false);
            black_box(&out2);
        });
        let bt: Vec<f32> = (0..n * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut out3 = vec![0.0f32; m * n];
        b.bench(&format!("{label}.bt"), || {
            mm_bt(&a, &bt, m, k, n, &mut out3, false);
            black_box(&out3);
        });
    }

    // full tiny LM train step
    let (k, nc, bt, t_len, de, hd) = (64usize, 128usize, 4usize, 8usize, 32usize, 64usize);
    let model = LmModel::new(de, hd, &mut rng);
    let mut emb = vec![0.0f32; k * de];
    rng.fill_normal(&mut emb, 0.1);
    let mut sm = vec![0.0f32; nc * de];
    rng.fill_normal(&mut sm, 0.1);
    let smb = vec![0.0f32; nc];
    let xs: Vec<i32> = (0..bt * t_len).map(|_| rng.below(k) as i32).collect();
    let ys: Vec<i32> = (0..bt * t_len).map(|_| rng.below(nc) as i32).collect();
    let h0 = vec![0.0f32; bt * hd];
    let c0 = vec![0.0f32; bt * hd];
    let mut grads = LmGrads::default();
    b.bench("lm_train_step/tiny", || {
        let out = model.train_step(&emb, k, &sm, &smb, nc, &xs, &ys, bt, t_len, &h0, &c0, &mut grads);
        black_box(out.loss);
    });
    b.bench("lm_eval_step/tiny", || {
        let out = model.eval_step(&emb, &sm, &smb, nc, &xs, &ys, bt, t_len, &h0, &c0);
        black_box(out.loss);
    });

    b.finish();
}
