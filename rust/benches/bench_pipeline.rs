//! Data-pipeline benches: corpus generation, BPTT batching, dedup planning,
//! candidate sampling, prefetch overhead.

use csopt::data::batcher::{BatchPlan, BpttBatcher};
use csopt::data::corpus::SyntheticCorpus;
use csopt::data::prefetch::PrefetchedBatches;
use csopt::train::sampler::CandidateSampler;
use csopt::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::from_env("pipeline");

    b.bench("corpus/zipf_gen.100k", || {
        let c = SyntheticCorpus::generate(8192, 100_000, 1.05, 0.6, 1);
        black_box(c.tokens.len());
    });

    let corpus = SyntheticCorpus::generate(32_768, 400_000, 1.05, 0.6, 2);
    b.bench("batcher/epoch.b32.t35", || {
        let mut batcher = BpttBatcher::new(&corpus.tokens, 32, 35);
        let mut n = 0;
        while let Some(w) = batcher.next_batch() {
            n += w.x.len();
        }
        black_box(n);
    });

    let mut batcher = BpttBatcher::new(&corpus.tokens, 32, 35);
    let batch = batcher.next_batch().unwrap();
    b.bench("plan/dedup.1120pos", || {
        let plan = BatchPlan::build(&batch.x, 1152, 0);
        black_box(plan.live);
    });

    let mut sampler = CandidateSampler::new(32_768, 2048, 3);
    b.bench("sampler/nc2048", || {
        let c = sampler.sample(&batch.y);
        black_box(c.ids.len());
    });

    b.bench("prefetch/epoch_overhead.b32.t35", || {
        let pre = PrefetchedBatches::start(corpus.tokens[..120_000].to_vec(), 32, 35, 4);
        let mut n = 0;
        while let Some(w) = pre.next() {
            n += w.x.len();
        }
        black_box(n);
    });

    b.finish();
}
